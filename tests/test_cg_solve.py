"""Staggered CG solver: the convergence-pinned test tier.

The flagship workload's correctness contracts:

  * ``ExecutionPlan.cg_solve`` on the shifted SPD operator
    ``A = sigma I + S`` matches the plain-jnp :func:`cg_reference_solve`
    oracle ITERATE BY ITERATE — every relative residual in the history,
    not just the final solution — within ``verify_tolerance`` across
    lattice size x layout x dtype x compression (hypothesis grid);
  * it converges on SU(3)-manifold gauge fields (constant per direction,
    so the site-local-adjoint stencil is exactly Hermitian) and the
    returned solution actually satisfies ``A x = b``;
  * exhausting ``max_iters`` RAISES ``CGMaxItersError`` — never hangs —
    with the iteration count and last residual on the exception;
  * the fused stencil+axpy iteration is BIT-IDENTICAL to the composed
    (separate axpy + stencil programs) iteration at f32 storage: same
    search direction, same operator product, same iterates, same scalars.
    The contract holds because the sigma shift runs in ONE shared jitted
    epilogue program for both paths (an in-kernel FMA contracts
    differently — see ``_su3_cg_fused_kernel``);
  * the same bit-identity holds on 1-, 2-, and 4-host forced-device
    meshes (subprocess via the shared conftest runner).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.core.su3.layouts import Layout
from repro.core.su3.plan import (
    CG_SHIFT,
    CGDivergedError,
    CGMaxItersError,
    EngineConfig,
    build_plan,
    cg_reference_solve,
    stencil_apply_reference,
    verify_tolerance,
)


def _su3_problem(L: int, seed: int = 7):
    """Constant-per-direction SU(3) links (QR + phase/det fix — exactly on
    the group manifold, and Hermitian under the stencil) + unit-scale b."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(4, 3, 3)) + 1j * rng.normal(size=(4, 3, 3))
    q, r = np.linalg.qr(a)
    d = np.diagonal(r, axis1=-2, axis2=-1)
    q = q * (d / np.abs(d))[..., None, :]
    q = q / np.linalg.det(q)[..., None, None] ** (1.0 / 3.0)
    n = L**4
    u = jnp.asarray(np.broadcast_to(q, (n, 4, 3, 3)).astype(np.complex64))
    b = jnp.asarray(
        (rng.normal(size=(n, 3)) + 1j * rng.normal(size=(n, 3))).astype(
            np.complex64))
    return u, b


def _plan_for(L, layout, dtype, accum, compression, tile=16):
    return build_plan(EngineConfig(
        L=L, dtype=dtype, accum_dtype=accum, layout=layout, tile=tile,
        iterations=1, warmups=0, compression=compression,
    ))


# -- convergence pin: iterate-by-iterate vs the jnp oracle --------------------


@settings(max_examples=8, deadline=None)
@given(
    L=st.sampled_from([2, 3]),
    layout=st.sampled_from([Layout.SOA, Layout.AOSOA]),
    precision=st.sampled_from([("float32", ""), ("bfloat16", "float32")]),
    compression=st.sampled_from(["none", "two_row"]),
)
def test_cg_matches_reference_iterate_by_iterate(L, layout, precision,
                                                 compression):
    dtype, accum = precision
    tol = 2e-2 if dtype == "bfloat16" else 1e-6
    plan = _plan_for(L, layout, dtype, accum, compression)
    u, b = _su3_problem(L)
    res = plan.cg_solve(plan.pack_gauge(u), plan.pack_rhs(b), tol=tol,
                        max_iters=64)
    assert res.converged and res.residuals[-1] <= tol
    _, ref_residuals, _ = cg_reference_solve(
        u, b, L, sigma=CG_SHIFT, tol=tol, max_iters=64)
    vt = verify_tolerance(dtype, accum,
                          reconstruct=compression == "two_row")
    # every iterate in the common prefix, not just the converged endpoint
    n_common = min(len(res.residuals), len(ref_residuals))
    assert n_common >= 1
    for i in range(n_common):
        assert abs(res.residuals[i] - ref_residuals[i]) <= vt, (
            i, res.residuals[i], ref_residuals[i])


def test_cg_converges_and_solves_the_system():
    """The solution is a solution: ``sigma x + S x`` reproduces b through
    the INDEPENDENT canonical-complex oracle, not the kernel path."""
    L = 3
    plan = _plan_for(L, Layout.SOA, "float32", "", "none")
    u, b = _su3_problem(L)
    res = plan.cg_solve(plan.pack_gauge(u), plan.pack_rhs(b), tol=1e-6,
                        max_iters=32)
    assert res.converged and res.iterations < 32
    x = plan.unpack_vec(res.x_p)
    ax = CG_SHIFT * x + stencil_apply_reference(u, x, L)
    rel = float(jnp.linalg.norm(ax - b) / jnp.linalg.norm(b))
    assert rel <= 1e-5


def test_cg_zero_rhs_is_immediate():
    plan = _plan_for(2, Layout.SOA, "float32", "", "none")
    u, _ = _su3_problem(2)
    res = plan.cg_solve(plan.pack_gauge(u),
                        plan.pack_rhs(jnp.zeros((16, 3), jnp.complex64)),
                        tol=1e-6, max_iters=4)
    assert res.converged and res.iterations == 0
    assert float(jnp.max(jnp.abs(res.x_p))) == 0.0


def test_cg_raises_not_hangs_on_max_iters():
    plan = _plan_for(2, Layout.SOA, "float32", "", "none")
    u, b = _su3_problem(2)
    with pytest.raises(CGMaxItersError) as ei:
        plan.cg_solve(plan.pack_gauge(u), plan.pack_rhs(b), tol=1e-30,
                      max_iters=3)
    assert ei.value.iterations == 3
    assert ei.value.residual > 1e-30
    assert "did not converge" in str(ei.value)


# -- the bit-identity contract ------------------------------------------------


def test_fused_composed_bit_identical_f32():
    """Fused stencil+axpy vs composed: every state array of every iterate
    bitwise equal at f32 storage, and the full solves agree exactly."""
    L = 2
    plan = _plan_for(L, Layout.SOA, "float32", "", "none", tile=8)
    u, b = _su3_problem(L)
    u_phys, b_p = plan.pack_gauge(u), plan.pack_rhs(b)

    sf = plan.cg_state_init(b_p)
    sc = plan.cg_state_init(b_p)
    for _ in range(5):
        sf = plan.cg_iterate(u_phys, sf, fused=True)
        sc = plan.cg_iterate(u_phys, sc, fused=False)
        for key in ("x", "r", "p", "rs"):
            a1 = np.asarray(jax.device_get(sf[key]))
            a2 = np.asarray(jax.device_get(sc[key]))
            assert np.array_equal(a1, a2), key

    rf = plan.cg_solve(u_phys, b_p, tol=1e-6, max_iters=32, fused=True)
    rc = plan.cg_solve(u_phys, b_p, tol=1e-6, max_iters=32, fused=False)
    assert rf.iterations == rc.iterations
    assert rf.residuals == rc.residuals
    assert np.array_equal(np.asarray(jax.device_get(rf.x_p)),
                          np.asarray(jax.device_get(rc.x_p)))


_MULTIHOST_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
import jax.numpy as jnp
from repro.core.su3.plan import EngineConfig, build_plan
from repro.launch.mesh import MeshSpec

rng = np.random.default_rng(7)
a = rng.normal(size=(4, 3, 3)) + 1j * rng.normal(size=(4, 3, 3))
q, r = np.linalg.qr(a)
d = np.diagonal(r, axis1=-2, axis2=-1)
q = q * (d / np.abs(d))[..., None, :]
q = q / np.linalg.det(q)[..., None, None] ** (1.0 / 3.0)
L = 4
n = L**4
u = jnp.asarray(np.broadcast_to(q, (n, 4, 3, 3)).astype(np.complex64))
b = jnp.asarray((rng.normal(size=(n, 3))
                 + 1j * rng.normal(size=(n, 3))).astype(np.complex64))

checked = []
cfg = EngineConfig(L=L, tile=32, iterations=1, warmups=0)
for hosts, dph in ((1, 4), (2, 2), (4, 1)):
    plan = build_plan(cfg, MeshSpec(hosts=hosts, devices_per_host=dph))
    u_phys, b_p = plan.pack_gauge(u), plan.pack_rhs(b)
    sf = plan.cg_state_init(b_p)
    sc = plan.cg_state_init(b_p)
    for _ in range(4):
        sf = plan.cg_iterate(u_phys, sf, fused=True)
        sc = plan.cg_iterate(u_phys, sc, fused=False)
        for key in ("x", "r", "p", "rs"):
            af = np.asarray(jax.device_get(sf[key]))
            ac = np.asarray(jax.device_get(sc[key]))
            assert np.array_equal(af, ac), (hosts, key)
    checked.append(hosts)
print(json.dumps(checked))
"""


def test_fused_composed_bit_identical_multihost_subprocess(
        forced_subprocess_json):
    """The bit-identity contract survives the multi-host overlap schedule:
    fused and composed iterates stay bitwise equal on 1-, 2-, and 4-host
    (slab-degenerate) forced-device meshes."""
    assert forced_subprocess_json(_MULTIHOST_SUBPROC) == [1, 2, 4]


# -- partial results and resume (ISSUE 9) -------------------------------------


def test_cg_max_iters_carries_partial_result_for_resume():
    """CGMaxItersError hands back the best iterate as a partial CGResult;
    resuming from it (``x0_p=err.result.x_p``) converges and solves the
    system.  CG is non-monotone in exact-arithmetic terms, so the resume
    contract is the warm start — the first resumed residual picks up near
    the partial's best — not an iteration-count saving."""
    L = 2
    plan = _plan_for(L, Layout.SOA, "float32", "", "none")
    u, b = _su3_problem(L)
    u_phys, b_p = plan.pack_gauge(u), plan.pack_rhs(b)
    with pytest.raises(CGMaxItersError) as ei:
        plan.cg_solve(u_phys, b_p, tol=1e-6, max_iters=4)
    err = ei.value
    assert err.result is not None and not err.result.converged
    assert err.result.iterations == 4
    assert len(err.result.residuals) == 4
    best = min(err.result.residuals)

    res = plan.cg_solve(u_phys, b_p, tol=1e-6, max_iters=64,
                        x0_p=err.result.x_p)
    assert res.converged
    # the warm start is real: the resumed run opens at the partial's best
    # residual scale, not at the cold start's ~1.0
    assert res.residuals[0] <= best * 4.0
    x = plan.unpack_vec(res.x_p)
    ax = CG_SHIFT * x + stencil_apply_reference(u, x, L)
    rel = float(jnp.linalg.norm(ax - b) / jnp.linalg.norm(b))
    assert rel <= 1e-5


def test_cg_diverges_structurally_on_non_finite_rhs():
    plan = _plan_for(2, Layout.SOA, "float32", "", "none")
    u, b = _su3_problem(2)
    bad = b.at[0, 0].set(jnp.nan)
    with pytest.raises(CGDivergedError) as ei:
        plan.cg_solve(plan.pack_gauge(u), plan.pack_rhs(bad), tol=1e-6,
                      max_iters=8)
    assert ei.value.reason == "non-finite right-hand side"
    assert ei.value.iterations == 0 and ei.value.result is None


def test_cg_diverges_structurally_on_non_finite_operator():
    plan = _plan_for(2, Layout.SOA, "float32", "", "none")
    u, b = _su3_problem(2)
    bad_u = u.at[0, 0, 0, 0].set(jnp.nan)
    with pytest.raises(CGDivergedError) as ei:
        plan.cg_solve(plan.pack_gauge(bad_u), plan.pack_rhs(b), tol=1e-6,
                      max_iters=8)
    assert ei.value.reason == "non-finite residual"
    assert ei.value.iterations == 1  # caught at the first residual sync
    # the poison hit before any finite iterate existed, so there is no
    # partial to resume from — result stays None rather than lying
    assert ei.value.result is None

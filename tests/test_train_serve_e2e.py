"""End-to-end: train loop with checkpoint resume; serving engine greedy
determinism and decode-vs-prefill consistency."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # smoke's fast tier skips these (-m "not slow")

from repro.configs import get_config
from repro.models import registry
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import ServeConfig, ServeEngine
from repro.train.loop import TrainConfig, train


def test_train_resume_continues_exactly():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    with tempfile.TemporaryDirectory() as d:
        t1 = TrainConfig(steps=8, seq_len=32, global_batch=2, checkpoint_dir=d,
                         checkpoint_every=4, log_every=4,
                         opt=AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=16))
        out1 = train(cfg, t1, log=lambda s: None)
        # resume to 16 steps
        t2 = TrainConfig(steps=16, seq_len=32, global_batch=2, checkpoint_dir=d,
                         checkpoint_every=8, log_every=4,
                         opt=AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=16))
        out2 = train(cfg, t2, log=lambda s: None)
        assert out2["final_loss"] is not None
        assert np.isfinite(out2["final_loss"])


def test_train_loss_decreases_dense():
    # 60 steps is inside the noise band on this config (~±0.03 nats around a
    # ~0.001/step trend); 160 steps gives a >0.1-nat margin over the Markov
    # data's learnable structure.
    cfg = get_config("qwen3-4b").reduced()
    tcfg = TrainConfig(steps=160, seq_len=64, global_batch=4, log_every=40,
                       opt=AdamWConfig(peak_lr=5e-3, warmup_steps=6, total_steps=160,
                                       weight_decay=0.0))
    out = train(cfg, tcfg, log=lambda s: None)
    assert out["losses"][-1] < out["losses"][0], out["losses"]


def test_serve_greedy_deterministic():
    cfg = get_config("yi-6b").reduced()
    api = registry.get(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, ServeConfig(max_len=48))
    prompts = np.ones((2, 8), np.int32) * 7
    a = eng.generate(prompts, 6)
    b = eng.generate(prompts, 6)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 14)
    # identical prompts -> identical continuations across rows
    np.testing.assert_array_equal(a[0], a[1])


def test_serve_hybrid_and_ssm_families():
    for arch in ("zamba2-1.2b", "xlstm-125m"):
        cfg = get_config(arch).reduced()
        api = registry.get(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(cfg, params, ServeConfig(max_len=32, cache_dtype="float32"))
        out = eng.generate(np.ones((2, 4), np.int32), 4)
        assert out.shape == (2, 8), arch
        assert np.all(out >= 0) and np.all(out < cfg.vocab_size), arch

"""Multi-tenant SLO control plane (ISSUE 10): quotas, deficit-fair
scheduling, brownout ladder, warm-pool autoscaling, seat preemption.

The pure-policy tests (TokenBucket, DeficitFairScheduler, BrownoutLadder,
WarmPoolAutoscaler) run in microseconds with no device.  The service-level
tests carry the ``tenancy`` marker and compile one or two tiny L=2 programs
each — ``scripts/smoke.sh`` runs the quota/brownout spot-check before the
tiers.
"""
import math
import time

import pytest

import jax
import jax.numpy as jnp

from repro.serve.su3 import (
    AutoscaleConfig,
    BatcherConfig,
    BrownoutConfig,
    BrownoutLadder,
    DeadlineExceededError,
    DeficitFairScheduler,
    LoadShedError,
    ServeRequest,
    ServiceConfig,
    SLOPolicy,
    SU3Service,
    TenantQuota,
    TokenBucket,
    WarmPoolAutoscaler,
)
from repro.serve.su3.tenancy import SLO_BULK, SLO_LATENCY

S2 = 16  # L=2 sites


def _rand_ab(seed, n_sites=S2):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n_sites, 4, 3, 3, 2))
    a = jax.lax.complex(g[..., 0], g[..., 1])
    h = jax.random.normal(jax.random.PRNGKey(seed + 10_000), (4, 3, 3, 2))
    return a, jax.lax.complex(h[..., 0], h[..., 1])


def _rand_rhs(seed, n_sites=S2):
    g = jax.random.normal(jax.random.PRNGKey(seed + 77), (n_sites, 3, 2))
    return jax.lax.complex(g[..., 0], g[..., 1])


def _svc(**kw):
    cfg = dict(autotune=False, tile=16)
    cfg.update(kw)
    return SU3Service(ServiceConfig(**cfg))


# -- TokenBucket (pure) --------------------------------------------------------


def test_token_bucket_pure_burst_is_deterministic():
    # rate_per_s=0 never refills: the bucket is a fixed burst budget no
    # matter how much (fake) time passes — what the reproducible benches use
    b = TokenBucket(TenantQuota(rate_per_s=0.0, burst=3))
    assert [b.try_take(t) for t in (0.0, 10.0, 20.0, 99.0)] == \
        [True, True, True, False]
    assert b.try_take(1e9) is False


def test_token_bucket_refills_on_the_callers_clock():
    b = TokenBucket(TenantQuota(rate_per_s=2.0, burst=2))
    assert b.try_take(0.0) and b.try_take(0.0)
    assert not b.try_take(0.0)  # dry
    assert not b.try_take(0.25)  # 0.5 tokens < 1
    assert b.try_take(0.75)  # +1.0 more by now
    # refill caps at burst: a long idle gap does not bank extra credit
    assert b.try_take(100.0) and b.try_take(100.0)
    assert not b.try_take(100.0)


def test_tenant_quota_validates():
    with pytest.raises(ValueError):
        TenantQuota(rate_per_s=-1.0)
    with pytest.raises(ValueError):
        TenantQuota(burst=0)


# -- DeficitFairScheduler (pure) -----------------------------------------------


def test_drr_alternates_equal_weights():
    sched = DeficitFairScheduler()
    groups = [("a", SLO_BULK), ("b", SLO_BULK)]
    served = [sched.next_group(groups) for _ in range(6)]
    assert served.count(groups[0]) == 3
    assert served.count(groups[1]) == 3
    assert served[0] != served[1]  # no back-to-back monopoly at weight 1


def test_drr_weight_proportionality():
    pol = SLOPolicy()  # latency_weight=4, bulk_weight=1
    sched = DeficitFairScheduler(weight_for=pol.weight_for)
    lat, bulk = ("t", SLO_LATENCY), ("t", SLO_BULK)
    served = [sched.next_group([lat, bulk]) for _ in range(50)]
    assert served.count(lat) == 40
    assert served.count(bulk) == 10


def test_drr_non_starvation_bound():
    # the documented bound: a weight-w group banks a turn within
    # ceil(1/(q*w)) ring visits, and every other group holds the floor at
    # most ceil(1 + q*weight_h) consecutive turns between visits — so even
    # against an adversarial heavy group the light one is served within
    # ceil(1/(q*w)) * sum_h ceil(1 + q*weight_h) calls
    weights = {("heavy", SLO_BULK): 8.0, ("light", SLO_BULK): 0.25}
    sched = DeficitFairScheduler(weight_for=lambda g: weights[g])
    ring = list(weights)
    bound = math.ceil(1.0 / 0.25) * sum(
        math.ceil(1.0 + w) for g, w in weights.items() if g[0] != "light")
    gap = 0
    worst = 0
    for _ in range(400):
        g = sched.next_group(ring)
        if g == ("light", SLO_BULK):
            worst = max(worst, gap)
            gap = 0
        else:
            gap += 1
    assert 0 < worst <= bound
    assert sched.turns[("light", SLO_BULK)] >= 400 // (bound + 4)


def test_drr_idle_group_forfeits_banked_credit():
    sched = DeficitFairScheduler()
    a, b = ("a", SLO_BULK), ("b", SLO_BULK)
    for _ in range(10):
        assert sched.next_group([a]) == a  # b idle throughout
    # b returns: it gets fair alternation, not a banked-burst monopoly
    served = [sched.next_group([a, b]) for _ in range(4)]
    assert served.count(b) == 2


def test_drr_idle_returns_none_and_recovers():
    sched = DeficitFairScheduler()
    a = ("a", SLO_BULK)
    assert sched.next_group([]) is None
    assert sched.next_group([a]) == a


# -- BrownoutLadder (pure) -----------------------------------------------------

_BCFG = BrownoutConfig(enter_pressure=0.8, exit_pressure=0.3,
                       sustain_turns=2, exit_turns=3)


def test_brownout_escalates_only_on_sustained_pressure():
    lad = BrownoutLadder(_BCFG)
    assert lad.observe(0.9) is None  # one hot sample is not a brownout
    assert lad.observe(0.9) == 1
    assert lad.rung == 1
    assert lad.observe(0.9) is None  # streak reset on transition
    assert lad.observe(0.9) == 2
    lad.observe(0.9)
    assert lad.observe(0.9) == 3
    assert [lad.observe(0.9) for _ in range(4)] == [None] * 4  # capped


def test_brownout_dead_band_and_exit_hysteresis():
    lad = BrownoutLadder(_BCFG)
    lad.observe(0.9)
    lad.observe(0.9)
    assert lad.rung == 1
    # dead band (0.3 < p < 0.8): neither streak advances
    for _ in range(10):
        assert lad.observe(0.5) is None
    assert lad.rung == 1
    # calm exits only after exit_turns consecutive calm samples
    assert lad.observe(0.1) is None
    assert lad.observe(0.1) is None
    assert lad.observe(0.1) == 0
    assert lad.rung == 0


def test_brownout_signature_is_replay_deterministic():
    trace = [0.9, 0.9, 0.5, 0.9, 0.9, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1]
    a, b = BrownoutLadder(_BCFG), BrownoutLadder(_BCFG)
    for p in trace:
        a.observe(p)
    for p in trace:
        b.observe(p)
    assert a.signature() == b.signature()
    assert a.signature()  # the trace does transition
    # turn indices (not wall clock) key the log
    assert all(isinstance(t, int) for t, _f, _to in a.signature())


# -- WarmPoolAutoscaler (pure) -------------------------------------------------

_ACFG = AutoscaleConfig(enabled=True, min_hosts=1, grow_queue_depth=4,
                        grow_occupancy=0.9, shrink_queue_depth=1,
                        shrink_occupancy=0.25, grow_turns=2, shrink_turns=3)


def test_autoscaler_grows_after_sustained_heat_and_respects_max():
    sc = WarmPoolAutoscaler(_ACFG, max_hosts=2)
    assert sc.observe(depth_per_host=8, occupancy=0.5, active=1) == 0
    assert sc.observe(depth_per_host=8, occupancy=0.5, active=1) == 1
    # at max_hosts the controller holds no matter how hot
    assert sc.observe(depth_per_host=8, occupancy=1.0, active=2) == 0
    assert sc.observe(depth_per_host=8, occupancy=1.0, active=2) == 0


def test_autoscaler_shrinks_after_sustained_cold_and_respects_min():
    sc = WarmPoolAutoscaler(_ACFG, max_hosts=3)
    for _ in range(2):
        assert sc.observe(depth_per_host=0, occupancy=0.0, active=2) == 0
    assert sc.observe(depth_per_host=0, occupancy=0.0, active=2) == -1
    for _ in range(6):
        assert sc.observe(depth_per_host=0, occupancy=0.0, active=1) == 0


def test_autoscaler_streak_resets_on_signal_flip():
    sc = WarmPoolAutoscaler(_ACFG, max_hosts=2)
    sc.observe(depth_per_host=8, occupancy=0.5, active=1)
    sc.observe(depth_per_host=0, occupancy=0.0, active=1)  # flip resets hot
    assert sc.observe(depth_per_host=8, occupancy=0.5, active=1) == 0
    assert sc.observe(depth_per_host=8, occupancy=0.5, active=1) == 1


def test_autoscale_config_validates_hysteresis():
    with pytest.raises(ValueError):
        AutoscaleConfig(grow_queue_depth=1, shrink_queue_depth=1)
    with pytest.raises(ValueError):
        AutoscaleConfig(grow_occupancy=0.2, shrink_occupancy=0.3)


# -- service-level: quotas, classes, brownout, preemption ----------------------


@pytest.mark.tenancy
def test_default_tenant_keeps_legacy_metrics_shape():
    svc = _svc()
    a, b = _rand_ab(0)
    rid = svc.submit(a, b, k=1)
    svc.run_until_drained()
    assert not isinstance(svc.pop_result(rid), Exception)
    snap = svc.metrics.snapshot()
    assert snap["admitted"] == 1 and snap["completed"] == 1
    assert snap["admitted_by_class"] == {"default/bulk": 1}
    assert list(snap["latency_by_class_ms"]) == ["default/bulk"]
    assert snap["brownout_rung"] == 0 and snap["quota_rejected"] == 0


@pytest.mark.tenancy
def test_quota_burst_rejects_at_the_front_door():
    svc = _svc(quotas={"metered": TenantQuota(rate_per_s=0.0, burst=2)})
    a, b = _rand_ab(1)
    ids = [svc.submit(a, b, k=1, tenant="metered") for _ in range(4)]
    assert ids[0] is not None and ids[1] is not None
    assert ids[2] is None and ids[3] is None  # bucket dry: rejected pre-queue
    # unmetered tenants never hit the bucket
    assert svc.submit(a, b, k=1, tenant="other") is not None
    snap = svc.metrics.snapshot()
    assert snap["quota_rejected"] == 2
    assert snap["quota_rejected_by_tenant"] == {"metered": 2}
    assert svc.queued() == 3
    svc.run_until_drained()


@pytest.mark.tenancy
def test_per_tenant_per_class_splits_sum_to_legacy_totals():
    svc = _svc()
    a, b = _rand_ab(2)
    svc.submit(a, b, k=1, tenant="t1")  # default bulk
    svc.submit(a, b, k=1, tenant="t2", slo="latency")
    u, _ = _rand_ab(3)
    svc.submit_stencil(u, _rand_rhs(3), tenant="t1")  # default latency
    svc.run_until_drained()
    snap = svc.metrics.snapshot()
    assert snap["completed"] == 3
    assert snap["admitted_by_class"] == {
        "t1/bulk": 1, "t2/latency": 1, "t1/latency": 1}
    assert sum(v["count"] for v in snap["latency_by_class_ms"].values()) == 3


@pytest.mark.tenancy
def test_shed_attributes_the_beneficiary_kind():
    svc = _svc(batcher=BatcherConfig(max_queue_depth=1))
    a, b = _rand_ab(4)
    rid_bulk = svc.submit(a, b, k=1)
    rid_solve = svc.submit_solve(a, _rand_rhs(4), tol=1e-3, max_iters=8)
    assert rid_bulk is not None and rid_solve is not None
    out = svc.pop_result(rid_bulk)
    assert isinstance(out, LoadShedError)
    assert out.shed_for_kind == "solve"
    snap = svc.metrics.snapshot()
    assert snap["shed_for_kind"] == {"solve": 1}  # the beneficiary, fixed
    assert snap["shed_by_kind"] == {"multiply": 1}  # the victim, unchanged
    assert snap["shed_by_class"] == {"default/bulk": 1}
    svc.run_until_drained()


@pytest.mark.tenancy
def test_latency_lane_is_never_shed():
    svc = _svc(batcher=BatcherConfig(max_queue_depth=1))
    a, b = _rand_ab(5)
    rid_lat = svc.submit(a, b, k=1, slo="latency")
    # a solve outranks multiplies by PRIORITY, but the seated request is
    # latency-class: nothing sheddable, so the solve is rejected instead
    rid_solve = svc.submit_solve(a, _rand_rhs(5), tol=1e-3, max_iters=8)
    assert rid_lat is not None and rid_solve is None
    assert svc.metrics.snapshot()["shed"] == 0
    svc.run_until_drained()
    assert not isinstance(svc.pop_result(rid_lat), Exception)


@pytest.mark.tenancy
def test_brownout_rung3_rejects_bulk_with_retry_after_hint():
    svc = _svc(brownout=BrownoutConfig(retry_after_s=0.25))
    svc._brownout.rung = 3  # pin the ladder at full brownout
    a, b = _rand_ab(6)
    rid = svc.submit(a, b, k=1)  # bulk: rejected at the door
    assert rid is not None  # zero-lost: the id resolves to a structured shed
    out = svc.pop_result(rid)
    assert isinstance(out, LoadShedError)
    assert out.shed_for_kind == "brownout"
    assert out.retry_after_s == pytest.approx(0.25)
    assert "retry after" in str(out)
    # the latency lane is never browned out
    rid_lat = svc.submit(a, b, k=1, slo="latency")
    assert rid_lat is not None and not svc.has_result(rid_lat)
    svc.run_until_drained()
    assert not isinstance(svc.pop_result(rid_lat), Exception)
    assert svc.metrics.snapshot()["shed_for_kind"] == {"brownout": 1}


@pytest.mark.tenancy
def test_brownout_rung1_caps_bulk_queue_share():
    svc = _svc(batcher=BatcherConfig(max_queue_depth=4),
               brownout=BrownoutConfig(bulk_queue_fraction=0.5))
    svc._brownout.rung = 1
    a, b = _rand_ab(7)
    ids = [svc.submit(a, b, k=1) for _ in range(3)]
    # bulk keeps floor(4 * 0.5) = 2 queue slots; the third arrival sheds
    assert svc.queued() == 2
    assert isinstance(svc.pop_result(ids[2]), LoadShedError)
    svc.run_until_drained()


@pytest.mark.tenancy
def test_brownout_rung2_degrades_bulk_solves():
    svc = _svc(brownout=BrownoutConfig(degrade_solve_factor=4),
               solve_iters_per_step=8)
    svc._brownout.rung = 2
    a, _ = _rand_ab(8)
    rid = svc.submit_solve(a, _rand_rhs(8), tol=1e-5, max_iters=64,
                           slo="bulk")
    svc.run_until_drained()
    assert not isinstance(svc.pop_result(rid), Exception)
    snap = svc.metrics.snapshot()
    assert snap["brownout_degraded_solve_turns"] >= 1
    # 8 iters/turn degraded to 2: more scheduling turns than the undegraded
    # solve would have used
    assert snap["kind_iterations"]["solve"] >= 2


@pytest.mark.tenancy
def test_latency_preempts_youngest_bulk_seat_continuous():
    svc = _svc(continuous=True, chain_slots=2,
               batcher=BatcherConfig(max_batch=2))
    a, b = _rand_ab(9)
    bulk_ids = [svc.submit(a, b, k=6) for _ in range(2)]
    svc.step()  # seat both bulk requests (k=6: they stay in flight)
    lat_id = svc.submit(a, b, k=1, slo="latency")
    done = svc.run_until_drained()
    assert done == 3
    assert svc.metrics.snapshot()["preemptions"] >= 1
    for rid in bulk_ids + [lat_id]:  # zero lost: preempted bulk re-ran
        assert not isinstance(svc.pop_result(rid), Exception)


@pytest.mark.tenancy
def test_autoscale_grows_under_backlog_and_shrinks_when_idle():
    svc = _svc(
        hosts=2,
        autoscale=AutoscaleConfig(
            enabled=True, min_hosts=1, grow_queue_depth=2,
            shrink_queue_depth=1, shrink_occupancy=0.25,
            grow_turns=1, shrink_turns=2),
    )
    assert svc._active_hosts == 1
    a, b = _rand_ab(10)
    ids = [svc.submit(a, b, k=1) for _ in range(4)]
    svc.run_until_drained()
    snap = svc.metrics.snapshot()
    assert snap["scale_ups"] >= 1  # backlog grew the pool
    for rid in ids:
        assert not isinstance(svc.pop_result(rid), Exception)
    for _ in range(8):  # idle: cold streak retires the extra host
        svc.step()
    snap = svc.metrics.snapshot()
    assert snap["scale_downs"] >= 1
    assert snap["active_hosts"] == 1


@pytest.mark.tenancy
def test_scale_down_vetoed_by_seated_latency_request():
    svc = _svc(hosts=2,
               autoscale=AutoscaleConfig(enabled=True, min_hosts=1))
    svc._active_hosts = 2
    seated = ServeRequest(req_id=7, a=None, b=None, L=2, k=1,
                          arrival_s=0.0, kind="solve", slo="latency")
    svc._solves[1] = {"req": seated}  # host 1 holds a seated latency solve
    svc._scale_down()
    assert svc._active_hosts == 2  # vetoed
    assert svc.metrics.snapshot()["scale_downs"] == 0
    del svc._solves[1]
    svc._scale_down()
    assert svc._active_hosts == 1


@pytest.mark.tenancy
def test_deficit_fair_turns_across_tenants_in_service():
    # two backlogged bulk tenants on one host split dispatch turns fairly
    svc = _svc(batcher=BatcherConfig(max_batch=1, max_queue_depth=64))
    a, b = _rand_ab(11)
    ids = []
    for i in range(4):
        ids.append(svc.submit(a, b, k=1, tenant="t1"))
        ids.append(svc.submit(a, b, k=1, tenant="t2"))
    svc.run_until_drained()
    for rid in ids:
        assert not isinstance(svc.pop_result(rid), Exception)
    turns = svc._sched.turns
    assert turns[("t1", "bulk")] == turns[("t2", "bulk")] == 4


@pytest.mark.tenancy
def test_slo_class_deadline_default_applies():
    svc = _svc(slo=SLOPolicy(bulk_deadline_s=0.001))
    a, b = _rand_ab(12)
    rid = svc.submit(a, b, k=1)  # bulk: inherits the 1 ms class deadline
    time.sleep(0.01)
    svc.step()
    out = svc.pop_result(rid)
    assert isinstance(out, DeadlineExceededError)
    # latency class has no default here: same traffic survives
    rid2 = svc.submit(a, b, k=1, slo="latency")
    time.sleep(0.01)
    svc.run_until_drained()
    assert not isinstance(svc.pop_result(rid2), Exception)

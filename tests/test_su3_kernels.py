"""Pallas SU3 kernel vs pure-jnp oracle: shape/dtype/tile sweeps + SU(3)
algebra property tests (hypothesis)."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.su3 import layouts, variants
from repro.kernels import ops, ref, su3_matmul


def _random_links(key, n_sites):
    a = jax.random.normal(key, (n_sites, 4, 3, 3, 2))
    return jax.lax.complex(a[..., 0], a[..., 1])


def _random_b(key):
    b = jax.random.normal(key, (4, 3, 3, 2))
    return jax.lax.complex(b[..., 0], b[..., 1])


@pytest.mark.parametrize("n_sites", [1, 7, 128, 300, 1024])
@pytest.mark.parametrize("tile", [128, 256])
def test_pallas_matches_ref_shapes(n_sites, tile):
    a = _random_links(jax.random.PRNGKey(n_sites), n_sites)
    b = _random_b(jax.random.PRNGKey(n_sites + 1))
    out = ops.su3_mult(a, b, tile=tile)
    expected = ref.su3_mult_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_pallas_planar_dtypes(dtype):
    n = 256
    a = _random_links(jax.random.PRNGKey(0), n)
    b = _random_b(jax.random.PRNGKey(1))
    a_p = layouts.pack_soa(a).reshape(2, su3_matmul.ROWS, n).astype(dtype)
    b_p = layouts.to_planar(b).reshape(2, su3_matmul.ROWS).astype(dtype)
    out = ops.su3_mult_planar(a_p, b_p, tile=128)
    expected = ref.su3_mult_planar_ref(
        a_p.astype(jnp.float32).reshape(2, 4, 3, 3, n),
        b_p.astype(jnp.float32).reshape(2, 4, 3, 3),
    ).reshape(2, su3_matmul.ROWS, n)
    tol = 5e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected), rtol=tol, atol=tol
    )


def test_vmem_budget():
    # paper's register-blocking lesson: the tile working set must fit VMEM
    from repro.core.roofline import TPU_V5E

    assert su3_matmul.vmem_bytes(ops.DEFAULT_TILE) < TPU_V5E.vmem_bytes


@pytest.mark.parametrize("variant", variants.variant_names())
def test_all_variants_match_ref(variant):
    a = _random_links(jax.random.PRNGKey(7), 384)
    b = _random_b(jax.random.PRNGKey(8))
    out = variants.get_variant(variant)(a, b)
    expected = ref.su3_mult_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Property tests: the kernel must respect SU(3) group structure.
# ---------------------------------------------------------------------------


def _random_su3(rng: np.random.Generator) -> np.ndarray:
    """Random special-unitary 3x3 via QR + phase fix."""
    z = rng.normal(size=(3, 3)) + 1j * rng.normal(size=(3, 3))
    q, r = np.linalg.qr(z)
    q = q * (np.diagonal(r) / np.abs(np.diagonal(r)))[None, :].conj()
    q = q / np.linalg.det(q) ** (1 / 3)
    return q.astype(np.complex64)


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(seed=st.integers(0, 2**31 - 1), n_sites=st.integers(1, 64))
def test_su3_closure_property(seed, n_sites):
    """SU(3) x SU(3) stays in SU(3): unit determinant, unitary product."""
    rng = np.random.default_rng(seed)
    a = np.stack([[_random_su3(rng) for _ in range(4)] for _ in range(n_sites)])
    b = np.stack([_random_su3(rng) for _ in range(4)])
    c = np.asarray(ops.su3_mult(jnp.asarray(a), jnp.asarray(b), tile=128))
    dets = np.linalg.det(c.reshape(-1, 3, 3))
    np.testing.assert_allclose(np.abs(dets), 1.0, atol=1e-4)
    prods = np.einsum("nij,nkj->nik", c.reshape(-1, 3, 3), c.reshape(-1, 3, 3).conj())
    np.testing.assert_allclose(prods, np.broadcast_to(np.eye(3), prods.shape), atol=1e-4)


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_linearity_property(seed):
    """C(alpha*A) == alpha*C(A) — the kernel is linear in A."""
    key = jax.random.PRNGKey(seed)
    a = _random_links(key, 128)
    b = _random_b(jax.random.fold_in(key, 1))
    alpha = 2.5 - 0.5j
    c1 = np.asarray(ops.su3_mult(alpha * a, b, tile=128))
    c2 = alpha * np.asarray(ops.su3_mult(a, b, tile=128))
    np.testing.assert_allclose(c1, c2, rtol=1e-4, atol=1e-4)


def test_paper_identity_check():
    """su3_bench validation: A=(1,0), B=(1/3,0) -> C elements == (1,0)."""
    n = 256
    a = jnp.full((n, 4, 3, 3), 1.0 + 0.0j, jnp.complex64)
    b = jnp.full((4, 3, 3), (1.0 / 3.0) + 0.0j, jnp.complex64)
    c = ops.su3_mult(a, b, tile=128)
    np.testing.assert_allclose(np.asarray(c), np.ones_like(np.asarray(c)), rtol=1e-6)

"""Persistent autotune cache: round-trip, corruption fallback, key isolation.

Sweeps are monkeypatched throughout — these tests pin the cache *protocol*
(what gets measured when, what gets persisted, what survives a bad file),
not kernel timings.
"""
import json
import os

import pytest

from repro.core import autotune
from repro.core.su3.layouts import Layout


def _patch_sweeps(monkeypatch, winners=None):
    """Replace tile_sweep/k_sweep with counting fakes.

    ``winners`` maps dtype -> (tile, k) so dtype-isolation tests can hand
    each dtype a distinguishable tuned tuple.
    """
    winners = winners or {}
    calls = {"tile": 0, "k": 0, "k_tile_arg": None, "tile_accum_arg": None}

    def fake_tile_sweep(tiles=(), L=8, dtype="float32", accum_dtype=""):
        calls["tile"] += 1
        calls["tile_accum_arg"] = accum_dtype
        tile = winners.get(accum_dtype or dtype, winners.get(dtype, (128, 4)))[0]
        return [
            {"tile": tile, "vmem_kib": 36, "fits_vmem": True,
             "measured_gflops": 2.0, "verified": True},
            {"tile": 4096, "vmem_kib": 1154, "fits_vmem": True,
             "measured_gflops": 1.0, "verified": True},
        ]

    def fake_k_sweep(ks=(1, 2, 4, 8), L=8, dtype="float32", tile=512, accum_dtype=""):
        calls["k"] += 1
        calls["k_tile_arg"] = tile
        k = winners.get(accum_dtype or dtype, winners.get(dtype, (128, 4)))[1]
        return [
            {"k": 1, "measured_gflops": 1.0, "verified": True},
            {"k": k, "measured_gflops": 3.0, "verified": True},
        ]

    monkeypatch.setattr(autotune, "tile_sweep", fake_tile_sweep)
    monkeypatch.setattr(autotune, "k_sweep", fake_k_sweep)
    return calls


def test_best_config_roundtrips_tile_and_fused_k(tmp_path, monkeypatch):
    calls = _patch_sweeps(monkeypatch)
    first = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert calls == {"tile": 1, "k": 1, "k_tile_arg": 128, "tile_accum_arg": ""}
    # measured winners, NOT the largest fitting tile / deepest chain
    assert first["tile"] == 128 and first["fused_k"] == 4
    assert first["cached"] is False
    second = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert calls["tile"] == 1 and calls["k"] == 1, "second call must not measure"
    assert second["tile"] == 128 and second["fused_k"] == 4
    assert second["cached"] is True
    # refresh forces a full re-measure
    autotune.best_config(L=4, cache_directory=str(tmp_path), refresh=True)
    assert calls["tile"] == 2 and calls["k"] == 2
    # the tuned tuple flows into an EngineConfig / the serving chain depth
    cfg = autotune.tuned_engine_config(L=4, cache_directory=str(tmp_path), iterations=1)
    assert cfg.tile == 128 and cfg.variant == "pallas" and cfg.layout == Layout.SOA
    assert autotune.tuned_fused_k(L=4, cache_directory=str(tmp_path)) == 4
    assert calls["tile"] == 2, "tuned_* helpers must hit the cache"


def test_corrupt_cache_file_remeasures_instead_of_crashing(tmp_path, monkeypatch):
    calls = _patch_sweeps(monkeypatch)
    path = os.path.join(str(tmp_path), autotune.CACHE_FILE)
    with open(path, "w") as f:
        f.write('{"cpu|cpu|soa|float32|L4|d1": {"config": {"til')  # truncated write
    cfg = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert cfg["tile"] == 128 and calls["tile"] == 1
    # the re-measure heals the file into valid JSON
    with open(path) as f:
        healed = json.load(f)
    (entry,) = healed.values()
    assert entry["config"]["fused_k"] == 4


@pytest.mark.parametrize("bad_entry", [
    "not-a-dict",
    {},
    {"config": "not-a-dict"},
    {"config": {"layout": "soa", "variant": "pallas", "tile": 128}},  # pre-fused_k schema
])
def test_partial_cache_entry_falls_back_to_measure(tmp_path, monkeypatch, bad_entry):
    calls = _patch_sweeps(monkeypatch)
    backend, device_kind, n_devices = autotune._device_identity()
    key = autotune.cache_key(backend=backend, device_kind=device_kind, layout="soa",
                             dtype="float32", L=4, n_devices=n_devices)
    autotune.store_cache_entry(key, bad_entry, str(tmp_path))
    cfg = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert cfg["cached"] is False and cfg["fused_k"] == 4
    assert calls["tile"] == 1, "partial entry must trigger a re-measure"
    # and the healed entry now serves from cache
    again = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert again["cached"] is True and calls["tile"] == 1


def test_cache_keys_isolate_dtypes(tmp_path, monkeypatch):
    calls = _patch_sweeps(monkeypatch, winners={
        "float32": (128, 4), "bfloat16": (256, 8),
    })
    f32 = autotune.best_config(L=4, dtype="float32", cache_directory=str(tmp_path))
    bf16 = autotune.best_config(L=4, dtype="bfloat16", cache_directory=str(tmp_path))
    assert calls["tile"] == 2, "each dtype pays its own sweep"
    assert (f32["tile"], f32["fused_k"]) == (128, 4)
    assert (bf16["tile"], bf16["fused_k"]) == (256, 8)
    # both cached independently — no cross-dtype hits or clobbering
    assert autotune.best_config(L=4, dtype="float32",
                                cache_directory=str(tmp_path))["tile"] == 128
    assert autotune.best_config(L=4, dtype="bfloat16",
                                cache_directory=str(tmp_path))["tile"] == 256
    assert calls["tile"] == 2
    cache = autotune.load_cache(str(tmp_path))
    assert len(cache) == 2 and {k.split("|")[3] for k in cache} == {
        "float32", "bfloat16"
    }


def test_mixed_precision_tunes_and_caches_separately(tmp_path, monkeypatch):
    """bf16-pure and bf16+f32-accum plans: own sweeps, own cache entries."""
    calls = _patch_sweeps(monkeypatch, winners={
        "bfloat16": (128, 2), "float32": (512, 8),  # accum key wins when set
    })
    pure = autotune.best_config(L=4, dtype="bfloat16", cache_directory=str(tmp_path))
    assert calls["tile_accum_arg"] == ""
    mixed = autotune.best_config(L=4, dtype="bfloat16", accum_dtype="float32",
                                 cache_directory=str(tmp_path))
    assert calls["tile_accum_arg"] == "float32", "sweeps must run as deployed"
    assert (pure["tile"], pure["fused_k"]) == (128, 2)
    assert (mixed["tile"], mixed["fused_k"]) == (512, 8)
    cache = autotune.load_cache(str(tmp_path))
    assert len(cache) == 2, "mixed precision must not alias the pure-dtype key"
    # both serve from cache now, each returning its own tuple
    assert autotune.tuned_fused_k(L=4, dtype="bfloat16",
                                  cache_directory=str(tmp_path)) == 2
    assert autotune.tuned_fused_k(L=4, dtype="bfloat16", accum_dtype="float32",
                                  cache_directory=str(tmp_path)) == 8
    assert calls["tile"] == 2
    # tuned_engine_config forwards the accum override into the tuning key
    cfg = autotune.tuned_engine_config(L=4, dtype="bfloat16",
                                       accum_dtype="float32",
                                       cache_directory=str(tmp_path))
    assert cfg.tile == 512 and cfg.accum_dtype == "float32"
    assert calls["tile"] == 2, "still zero new measurements"


def test_cache_key_identity():
    k = autotune.cache_key(backend="tpu", device_kind="v5e", layout="soa",
                           dtype="bfloat16", L=16, n_devices=4)
    assert k == "tpu|v5e|soa|bfloat16|L16|d4"

"""Persistent autotune cache: round-trip, corruption fallback, key isolation,
and the v2 (pipeline) schema bump.

Measurements and the instruction-model lowering are monkeypatched throughout —
these tests pin the cache *protocol* (what gets measured when, what gets
persisted, what survives a bad file or an old-schema entry), not kernel
timings.  The pruning/selection quality of the sweep itself is covered by
``test_autotune_pruning.py``.
"""
import json
import os

import pytest

from repro.core import autotune
from repro.core.su3.layouts import Layout

# four candidates whose model ranking (with the patched instruction model)
# is deterministic: (512, 8) > (128, 4) > (256, 2) > (4096, 1); the default
# prune=0.5 measures the top TWO only.
_CANDS = (
    autotune.PipelineCandidate(128, 4),
    autotune.PipelineCandidate(256, 2),
    autotune.PipelineCandidate(4096, 1),
    autotune.PipelineCandidate(512, 8),
)


def _patch_pipeline(monkeypatch, winners=None):
    """Replace the measurement + instruction-model with counting fakes.

    ``winners`` maps dtype (or, when set, accum_dtype) -> (tile, fused_k):
    that candidate measures 3.0 GF/s, everything else 1.0, so dtype-isolation
    tests can hand each dtype a distinguishable tuned tuple.  Winners must
    sit in the model's top half — (128, 4) and (512, 8) do.
    """
    winners = winners or {}
    calls = {"measure": 0, "accum_arg": None, "cands": []}

    monkeypatch.setattr(
        autotune, "kernel_instruction_model",
        lambda dtype="float32", accum_dtype="", tile=256, compression="none": (100.0, 50.0),
    )
    monkeypatch.setattr(
        autotune, "enumerate_candidates",
        lambda tiles=(), ks=(), dtype="float32", accum_dtype="", hw=None: list(_CANDS),
    )

    def fake_measure(cand, L=8, dtype="float32", accum_dtype="", compression="none"):
        calls["measure"] += 1
        calls["accum_arg"] = accum_dtype
        calls["cands"].append((cand.tile, cand.fused_k))
        win = winners.get(accum_dtype or dtype, winners.get(dtype, (128, 4)))
        gf = 3.0 if (cand.tile, cand.fused_k) == win else 1.0
        return {"tile": cand.tile, "fused_k": cand.fused_k, "vmem_kib": 36,
                "measured_gflops": gf, "verified": True}

    monkeypatch.setattr(autotune, "measure_candidate", fake_measure)
    return calls


def test_best_config_roundtrips_pipeline_tuple(tmp_path, monkeypatch):
    calls = _patch_pipeline(monkeypatch)
    first = autotune.best_config(L=4, cache_directory=str(tmp_path))
    # pruned: top HALF of the 4-candidate set measured, ranked model-first
    assert calls["measure"] == 2
    assert calls["cands"] == [(512, 8), (128, 4)]
    # measured winner among the pruned set, NOT the model's favorite
    assert first["tile"] == 128 and first["fused_k"] == 4
    assert first["cached"] is False
    assert first["pipeline"]["schema"] == autotune.SCHEMA_VERSION
    assert first["pipeline"]["candidates_total"] == 4
    assert first["pipeline"]["candidates_measured"] == 2
    second = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert calls["measure"] == 2, "second call must not measure"
    assert second["tile"] == 128 and second["fused_k"] == 4
    assert second["cached"] is True
    # refresh forces a full re-measure
    autotune.best_config(L=4, cache_directory=str(tmp_path), refresh=True)
    assert calls["measure"] == 4
    # the tuned tuple flows into an EngineConfig / the serving chain depth
    cfg = autotune.tuned_engine_config(L=4, cache_directory=str(tmp_path), iterations=1)
    assert cfg.tile == 128 and cfg.variant == "pallas" and cfg.layout == Layout.SOA
    assert autotune.tuned_fused_k(L=4, cache_directory=str(tmp_path)) == 4
    assert calls["measure"] == 4, "tuned_* helpers must hit the cache"


def test_corrupt_cache_file_remeasures_instead_of_crashing(tmp_path, monkeypatch):
    calls = _patch_pipeline(monkeypatch)
    path = os.path.join(str(tmp_path), autotune.CACHE_FILE)
    with open(path, "w") as f:
        f.write('{"v2|cpu|cpu|soa|float32|L4|d1": {"config": {"til')  # truncated
    cfg = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert cfg["tile"] == 128 and calls["measure"] == 2
    # the re-measure heals the file into valid JSON with full provenance
    with open(path) as f:
        healed = json.load(f)
    (entry,) = healed.values()
    assert entry["config"]["fused_k"] == 4
    assert entry["config"]["pipeline"]["candidates_measured"] == 2


@pytest.mark.parametrize("bad_entry", [
    "not-a-dict",
    {},
    {"config": "not-a-dict"},
    {"config": {"layout": "soa", "variant": "pallas", "tile": 128}},  # pre-fused_k
    # pre-pipeline (v1) schema written under a v2 key (e.g. hand-edited):
    # must re-measure, never be served with the pipeline block missing
    {"config": {"layout": "soa", "variant": "pallas", "tile": 128, "fused_k": 4}},
])
def test_partial_cache_entry_falls_back_to_measure(tmp_path, monkeypatch, bad_entry):
    calls = _patch_pipeline(monkeypatch)
    backend, device_kind, n_devices = autotune._device_identity()
    key = autotune.cache_key(backend=backend, device_kind=device_kind, layout="soa",
                             dtype="float32", L=4, n_devices=n_devices)
    autotune.store_cache_entry(key, bad_entry, str(tmp_path))
    cfg = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert cfg["cached"] is False and cfg["fused_k"] == 4
    assert calls["measure"] == 2, "partial entry must trigger a re-measure"
    # and the healed entry now serves from cache
    again = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert again["cached"] is True and calls["measure"] == 2


def test_v1_schema_entries_never_match_the_v2_key(tmp_path, monkeypatch):
    """The schema bump: a pre-pipeline cache file (unversioned keys, no
    ``pipeline`` block) is a clean miss — re-measured, not crashed on, and
    left in place next to the new v2 entry."""
    calls = _patch_pipeline(monkeypatch)
    backend, device_kind, n_devices = autotune._device_identity()
    v1_key = f"{backend}|{device_kind}|soa|float32|L4|d{n_devices}"  # old format
    autotune.store_cache_entry(
        v1_key,
        {"config": {"layout": "soa", "variant": "pallas", "tile": 4096,
                    "fused_k": 1},
         "measured_gflops": 9.9, "key": v1_key},
        str(tmp_path),
    )
    cfg = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert calls["measure"] == 2, "v1 entry must not be served"
    assert (cfg["tile"], cfg["fused_k"]) == (128, 4), "fresh sweep decides"
    cache = autotune.load_cache(str(tmp_path))
    assert set(cache) == {v1_key, autotune.cache_key(
        backend=backend, device_kind=device_kind, layout="soa",
        dtype="float32", L=4, n_devices=n_devices)}


def test_cache_keys_isolate_dtypes(tmp_path, monkeypatch):
    calls = _patch_pipeline(monkeypatch, winners={
        "float32": (128, 4), "bfloat16": (512, 8),
    })
    f32 = autotune.best_config(L=4, dtype="float32", cache_directory=str(tmp_path))
    bf16 = autotune.best_config(L=4, dtype="bfloat16", cache_directory=str(tmp_path))
    assert calls["measure"] == 4, "each dtype pays its own (pruned) sweep"
    assert (f32["tile"], f32["fused_k"]) == (128, 4)
    assert (bf16["tile"], bf16["fused_k"]) == (512, 8)
    # both cached independently — no cross-dtype hits or clobbering
    assert autotune.best_config(L=4, dtype="float32",
                                cache_directory=str(tmp_path))["tile"] == 128
    assert autotune.best_config(L=4, dtype="bfloat16",
                                cache_directory=str(tmp_path))["tile"] == 512
    assert calls["measure"] == 4
    cache = autotune.load_cache(str(tmp_path))
    assert len(cache) == 2 and {k.split("|")[4] for k in cache} == {
        "float32", "bfloat16"
    }


def test_mixed_precision_tunes_and_caches_separately(tmp_path, monkeypatch):
    """bf16-pure and bf16+f32-accum plans: own sweeps, own cache entries."""
    calls = _patch_pipeline(monkeypatch, winners={
        "bfloat16": (128, 4), "float32": (512, 8),  # accum key wins when set
    })
    pure = autotune.best_config(L=4, dtype="bfloat16", cache_directory=str(tmp_path))
    assert calls["accum_arg"] == ""
    mixed = autotune.best_config(L=4, dtype="bfloat16", accum_dtype="float32",
                                 cache_directory=str(tmp_path))
    assert calls["accum_arg"] == "float32", "sweeps must run as deployed"
    assert (pure["tile"], pure["fused_k"]) == (128, 4)
    assert (mixed["tile"], mixed["fused_k"]) == (512, 8)
    cache = autotune.load_cache(str(tmp_path))
    assert len(cache) == 2, "mixed precision must not alias the pure-dtype key"
    # both serve from cache now, each returning its own tuple
    assert autotune.tuned_fused_k(L=4, dtype="bfloat16",
                                  cache_directory=str(tmp_path)) == 4
    assert autotune.tuned_fused_k(L=4, dtype="bfloat16", accum_dtype="float32",
                                  cache_directory=str(tmp_path)) == 8
    assert calls["measure"] == 4
    # tuned_engine_config forwards the accum override into the tuning key
    cfg = autotune.tuned_engine_config(L=4, dtype="bfloat16",
                                       accum_dtype="float32",
                                       cache_directory=str(tmp_path))
    assert cfg.tile == 512 and cfg.accum_dtype == "float32"
    assert calls["measure"] == 4, "still zero new measurements"


def test_cache_key_identity():
    k = autotune.cache_key(backend="tpu", device_kind="v5e", layout="soa",
                           dtype="bfloat16", L=16, n_devices=4)
    assert k == "v3|tpu|v5e|soa|bfloat16|none|L16|d4"
    kc = autotune.cache_key(backend="tpu", device_kind="v5e", layout="soa",
                            dtype="bfloat16", L=16, n_devices=4,
                            compression="two_row")
    assert kc == "v3|tpu|v5e|soa|bfloat16|two_row|L16|d4"
    # a v2-era key (no compression segment) can never equal any v3 key
    assert "v2|tpu|v5e|soa|bfloat16|L16|d4" != k

"""Roofline math + HLO cost model unit tests (synthetic HLO text)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_costs, roofline

SYNTH_HLO = """
HloModule synth

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %d = f32[128,128]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%d), replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[128,128]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128]{1,0} parameter(0)
  %i0 = s32[] constant(0)
  %t0 = (s32[], f32[128,128]) tuple(%i0, %a)
  %w = (s32[], f32[128,128]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[256,128]{1,0} all-gather(%a), replica_groups=[1,2]<=[2], dimensions={0}
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_hlo_costs():
    cost = hlo_costs.analyze_hlo(SYNTH_HLO)
    # dot: 2*128*128*128 = 4.19e6 flops x 5 trips
    assert cost.flops == pytest.approx(5 * 2 * 128**3, rel=0.05)
    # all-reduce in loop: 2*(3/4)*65536 bytes x 5; all-gather: (1/2)*131072
    ar = 5 * 2 * (3 / 4) * 128 * 128 * 4
    ag = (1 / 2) * 256 * 128 * 4
    assert cost.collective_link_bytes == pytest.approx(ar + ag, rel=0.01)
    assert cost.collective_by_kind["all-reduce"] == pytest.approx(ar, rel=0.01)
    assert cost.collective_by_kind["all-gather"] == pytest.approx(ag, rel=0.01)


def test_tuple_shape_with_index_comments():
    txt = """
ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %t = (f32[4]{0}, /*index=1*/f32[8,2]{1,0}) tuple(%a, %a)
  ROOT %o = f32[4]{0} add(%a, %a)
}
"""
    comps, entry, _ = hlo_costs.parse_computations(txt)
    assert "t" in comps[entry].instructions  # the /*index=1*/ comment parses


def test_real_scan_trip_count_accounting():
    """cost_analysis counts while bodies once; our model multiplies them."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, jnp.zeros((8, 64)), None, length=10)
        return out

    compiled = jax.jit(f).lower(w).compile()
    ours = hlo_costs.analyze_hlo(compiled.as_text())
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [per-device dict]
        ca = ca[0] if ca else {}
    theirs = float(ca.get("flops", 0.0))
    expected_dots = 10 * 2 * 8 * 64 * 64
    assert ours.flops >= expected_dots * 0.95
    assert theirs < expected_dots * 0.5  # XLA undercounts -> why we parse


# -- roofline report math ----------------------------------------------------


def test_collective_ring_models():
    mk = lambda kind, b, n: roofline.CollectiveOp(kind, b, n)
    assert mk("all-reduce", 100, 4).link_bytes == pytest.approx(2 * 3 / 4 * 100)
    assert mk("all-gather", 100, 4).link_bytes == pytest.approx(3 / 4 * 100)
    assert mk("reduce-scatter", 25, 4).link_bytes == pytest.approx(3 * 25)
    assert mk("collective-permute", 100, 2).link_bytes == 100
    assert mk("all-reduce", 100, 1).link_bytes == 0.0


def test_report_dominance_and_fraction():
    r = roofline.RooflineReport(
        name="t", hw=roofline.TPU_V5E, n_chips=4,
        flops_per_device=197e12,  # exactly 1s compute
        bytes_per_device=819e9 * 2,  # 2s memory
        collective_link_bytes=50e9 * 0.5,  # 0.5s collective
        collective_by_kind={}, model_flops=4 * 197e12,
    )
    assert r.dominant == "memory"
    assert r.bound_s == pytest.approx(2.0)
    assert r.compute_s == pytest.approx(1.0)
    assert r.useful_flops_ratio == pytest.approx(1.0)
    assert r.roofline_fraction == pytest.approx(0.5)


def test_analytic_su3_report_is_bandwidth_bound():
    rep = roofline.analytic_su3_report(
        n_sites=32**4, word_bytes=4, bytes_per_site_rw=576, n_chips=1
    )
    assert rep.dominant == "memory"
    # AI=1.5 on SoA; VPU ridge = 1.9e12/819e9 = 2.3 flop/byte -> memory-bound
    assert rep.memory_s > rep.compute_s


def test_instruction_mix_counted_loop_aware():
    cost = hlo_costs.analyze_hlo(SYNTH_HLO)
    # body (x5 trips): dot + cond's compare -> 10 arith; all-reduce x5 plus
    # the entry all-gather -> 6 collective; the while op itself -> 1 control
    assert cost.instr_by_class["arith"] == pytest.approx(10)
    assert cost.instr_by_class["collective"] == pytest.approx(6)
    assert cost.instr_by_class["control"] == pytest.approx(1)
    assert cost.instructions == pytest.approx(
        sum(cost.instr_by_class.values())
    )


def test_issue_term_reproduces_piuma_pipeline_bound():
    """Paper §5.3: 12 loads + 2 stores + 12 FMAs per 24 flops — SU3 on PIUMA
    is bounded by the ISSUE rate (3.6 GF/s), below both the 8 GF/s FMA roof
    and the 4.32 GF/s bandwidth bound.  The three-term report must reproduce
    that: issue dominant, effective throughput ~3.6 GF/s."""
    n = 10_000  # sites
    rep = roofline.RooflineReport(
        name="piuma_su3", hw=roofline.PIUMA_CORE, n_chips=1,
        flops_per_device=24.0 * n,
        bytes_per_device=24.0 / 0.675 * n,  # AI = 0.675 (fp64)
        collective_link_bytes=0.0, collective_by_kind={},
        instructions_per_device=26.0 * n,
    )
    assert rep.issue_s > 0
    assert rep.dominant == "issue"
    assert rep.flops_per_device / rep.bound_s == pytest.approx(3.6e9, rel=0.02)


def test_issue_term_absent_without_instruction_counts():
    r = roofline.RooflineReport(
        name="t", hw=roofline.TPU_V5E, n_chips=1,
        flops_per_device=1e12, bytes_per_device=819e9,
        collective_link_bytes=0.0, collective_by_kind={},
    )
    assert r.issue_s == 0.0  # unmeasured -> two/three-term users unaffected
    assert r.dominant == "memory"


def test_xeon_piuma_models_match_paper():
    """Paper §4/§5.3 platform models. (The paper states 17.1 = 2420.1/105.0,
    which is arithmetically 23.05 — we keep the stated inputs, so our ridge
    is 23.05; the discrepancy is the paper's, noted in EXPERIMENTS.md.)"""
    assert roofline.XEON_8280_SOCKET.ridge_flops_per_byte == pytest.approx(
        2420.1 / 105.0, rel=0.01
    )
    assert roofline.PIUMA_CORE.ridge_flops_per_byte < 3.0
    # PIUMA compute-bound 8 GF/s FMA; bandwidth-bound 4.32 GF/s at AI=0.675
    assert roofline.PIUMA_CORE.hbm_bw * 0.675 == pytest.approx(4.32e9, rel=0.01)

"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED config of the same family and runs one
forward/train step on CPU asserting output shapes + no NaNs, plus one
prefill + decode step through the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.models import registry

SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    api = registry.get(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = registry.make_inputs(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    loss, metrics = api.loss_fn(params, batch, cfg, remat=True, q_chunk=8, kv_chunk=8)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    grads = jax.grad(lambda p: api.loss_fn(p, batch, cfg, remat=True,
                                           q_chunk=8, kv_chunk=8)[0])(params)
    gleaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in gleaves), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    api = registry.get(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    b, plen, max_len = 2, 16, 32
    state = api.init_state(cfg, b, max_len, jnp.float32)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, plen), 0, cfg.vocab_size)}
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(jax.random.PRNGKey(2), (b, cfg.n_patches, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(3), (b, cfg.encoder_len, cfg.d_model))
    logits, state = api.prefill(params, batch, state, cfg, q_chunk=8, kv_chunk=8)
    assert logits.shape == (b, 1, cfg.vocab_size), arch
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, state = api.decode_step(params, {"tokens": tok}, state, jnp.int32(plen), cfg)
    assert logits2.shape == (b, 1, cfg.vocab_size), arch
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch


def test_exact_assigned_dims():
    """The full configs must carry the exact assignment dimensions."""
    expect = {
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }
    for arch, (L, d, h, kv, dff, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (L, d, h, kv), arch
        assert c.vocab_size == v, arch
        if arch not in ("deepseek-v3-671b",):
            assert c.d_ff == dff or c.d_ff_expert == dff, arch
    ds = get_config("deepseek-v3-671b")
    assert (ds.n_layers, ds.d_model, ds.n_heads) == (61, 7168, 128)
    assert (ds.n_experts, ds.experts_per_token, ds.d_ff_expert) == (256, 8, 2048)
    assert ds.vocab_size == 129280 and ds.use_mla and ds.mtp_depth == 1
    # param counts near nameplate
    assert 600e9 < ds.n_params() < 750e9
    assert 30e9 < ds.active_params() < 45e9  # ~37B active


def test_decode_matches_teacher_forcing():
    """Greedy decode logits == teacher-forcing forward logits (dense arch)."""
    from repro.models import transformer

    cfg = get_config("yi-6b").reduced()
    api = registry.get(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    # full forward logits at the last position
    x, _, _ = transformer.forward(params, {"tokens": toks}, cfg, q_chunk=8, kv_chunk=8)
    full_logits = transformer._logits(params, x, cfg)
    # serving path: prefill 11 tokens, decode the 12th
    state = api.init_state(cfg, 2, 16, jnp.float32)
    _, state = api.prefill(params, {"tokens": toks[:, :11]}, state, cfg, q_chunk=8, kv_chunk=8)
    logits, _ = api.decode_step(params, {"tokens": toks[:, 11:12]}, state, jnp.int32(11), cfg)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, -1]), rtol=2e-3, atol=2e-3
    )

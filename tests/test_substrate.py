"""Substrate tests: data pipeline, optimizer, compression, checkpointing,
fault tolerance, sharding resolver, HLO cost model."""
import os
import pathlib
import tempfile

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed.fault_tolerance import (
    ElasticMeshPlanner, HeartbeatMonitor, straggler_safe_step_budget,
)
from repro.optim import adamw, compression


# -- data pipeline ------------------------------------------------------------


def test_pipeline_deterministic():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch_at(7), p2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_shards_partition_global_batch():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=0)
    full = TokenPipeline(cfg).batch_at(3)["tokens"]
    parts = [
        TokenPipeline(cfg, shard_index=i, shard_count=4).batch_at(3)["tokens"]
        for i in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_pipeline_labels_shift():
    cfg = DataConfig(vocab_size=50, seq_len=12, global_batch=2, seed=1)
    b = TokenPipeline(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    np.testing.assert_array_equal(b["labels"][:, 1:], b["labels2"][:, :-1])


@hypothesis.settings(deadline=None, max_examples=10)
@hypothesis.given(step=st.integers(0, 1000))
def test_pipeline_markov_structure(step):
    """every token is a legal successor of its predecessor."""
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=1, seed=5, branching=4)
    p = TokenPipeline(cfg)
    toks = p.batch_at(step)["tokens"][0]
    for t in range(1, len(toks)):
        assert toks[t] in p._succ[toks[t - 1]]


# -- optimizer ------------------------------------------------------------------


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.ones(8) * 5.0}
    state = adamw.init(params, cfg)
    for _ in range(60):
        grads = {"w": params["w"]}  # d/dw 0.5*w^2
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1.0


def test_adamw_schedule_shape():
    cfg = adamw.AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params, cfg)
    _, _, m = adamw.update({"w": jnp.ones(4) * 100.0}, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# -- compression ------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_compression_error_feedback_bounded(mode):
    """EF keeps the accumulated error bounded across steps."""
    cfg = compression.CompressionConfig(mode=mode)
    params = {"w": jnp.zeros(64)}
    err = compression.init_error_state(params, cfg)
    rng = np.random.default_rng(0)
    errs = []
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=64), jnp.float32)}
        g2, err, m = compression.apply_error_feedback(g, err, cfg)
        errs.append(float(m["compression_err"]))
    # error stays bounded (no drift)
    assert errs[-1] < 10 * (np.mean(errs[:10]) + 1e-6)


def test_compression_preserves_mean_signal():
    """sum over steps of compressed grads ~= sum of true grads (EF property)."""
    cfg = compression.CompressionConfig(mode="int8")
    err = compression.init_error_state({"w": jnp.zeros(16)}, cfg)
    rng = np.random.default_rng(1)
    tot_true = np.zeros(16)
    tot_comp = np.zeros(16)
    for _ in range(100):
        g = rng.normal(size=16).astype(np.float32)
        tot_true += g
        g2, err, _ = compression.apply_error_feedback({"w": jnp.asarray(g)}, err, cfg)
        tot_comp += np.asarray(g2["w"])
    np.testing.assert_allclose(tot_comp, tot_true, atol=0.2)


# -- checkpointing ------------------------------------------------------------------


def test_checkpoint_roundtrip_and_retention():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(d, keep=2, async_save=False))
        tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 3))}}
        for s in (1, 2, 3):
            mgr.save(s, jax.tree.map(lambda x: x * s, tree), {"pipeline_step": s * 10})
        assert mgr.all_steps() == [2, 3]  # retention pruned step 1
        restored, extra, step = mgr.restore(tree)
        assert step == 3 and extra["pipeline_step"] == 30
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5) * 3)


def test_checkpoint_ignores_partial(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(str(tmp_path), async_save=False))
    mgr.save(5, {"a": jnp.ones(3)})
    # simulate a crashed writer: partial dir without manifest
    (tmp_path / "step_00000009").mkdir()
    assert mgr.latest_step() == 5
    # and a .tmp leftover
    (tmp_path / "step_00000011.tmp").mkdir()
    assert mgr.latest_step() == 5


def test_checkpoint_async():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(d, async_save=True))
        mgr.save(1, {"a": jnp.zeros(10)})
        mgr.wait()
        assert mgr.latest_step() == 1


# -- fault tolerance -------------------------------------------------------------


def test_heartbeat_dead_and_stragglers():
    mon = HeartbeatMonitor(["h0", "h1", "h2"], deadline_s=10, straggler_factor=2.0)
    now = 1000.0
    mon.beat("h0", 1.0, now=now)
    mon.beat("h1", 1.1, now=now)
    mon.beat("h2", 5.0, now=now)
    for _ in range(20):  # converge EWMA
        mon.beat("h0", 1.0, now=now)
        mon.beat("h1", 1.1, now=now)
        mon.beat("h2", 5.0, now=now)
    assert mon.stragglers() == ["h2"]
    assert mon.dead(now=now + 11)[0:3] == ["h0", "h1", "h2"]
    mon.beat("h0", now=now + 11)
    assert "h0" not in mon.dead(now=now + 11)


def test_elastic_mesh_planner():
    p = ElasticMeshPlanner(devices_per_host=4, model_axis=16, global_batch=256)
    plan = p.plan(alive_hosts=[f"h{i}" for i in range(60)], dead_hosts=["h60", "h61"])
    assert plan.n_devices <= 240
    assert plan.model == 16  # model axis preserved
    assert 256 % plan.data == 0
    # catastrophic loss: model axis must shrink
    plan2 = p.plan(alive_hosts=["h0", "h1"], dead_hosts=[])
    assert plan2.model <= 8 and plan2.n_devices == 8


def test_straggler_budget():
    assert straggler_safe_step_budget([1.0, 1.1, 0.9], 2.0) == pytest.approx(2.0)

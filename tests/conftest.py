"""Test-environment shims and shared forced-device subprocess plumbing.

Forced host-platform device counts (``--xla_force_host_platform_device_count``)
lock at first jax init, so every multi-host test runs its mesh code in a
fresh subprocess.  The launcher boilerplate (env, PYTHONPATH, timeout,
stderr-on-failure, last-stdout-line JSON protocol) used to be copy-pasted
across test modules; it now lives here once as
:func:`run_forced_device_subprocess` / the ``forced_subprocess_json``
fixture, mirroring ``benchmarks.stencil._subprocess_json`` on the
benchmark side.

``hypothesis`` is not installed in every container this repo runs in, but five
test modules import it at module scope, which used to abort collection of the
whole suite (``pytest -x`` stops at the first ImportError).  When the real
package is available we use it untouched; otherwise we install a *minimal
deterministic fallback* into ``sys.modules`` before test modules are imported.

The fallback covers exactly the API surface the suite uses:

  * ``hypothesis.settings(...)``  -> identity decorator (options ignored)
  * ``hypothesis.given(**kw)``    -> runs the test over the cartesian product
    of each strategy's deterministic example set (capped), so property tests
    still execute with boundary + interior values instead of being skipped
  * ``strategies.integers(lo, hi)`` / ``strategies.sampled_from(seq)``

This is intentionally not a property-based tester — it is a degraded mode
that keeps the suite green and the non-hypothesis tests in those modules
running.  Install ``hypothesis`` to get real randomized coverage.
"""
from __future__ import annotations

import functools
import json
import os
import pathlib
import subprocess
import sys
import types

import pytest

_MAX_FALLBACK_EXAMPLES = 5

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def run_forced_device_subprocess(code: str, timeout: int = 420):
    """Run ``code`` in a fresh interpreter and return its last-stdout-line
    JSON payload.

    The snippet is expected to set ``XLA_FLAGS`` (forced host-platform
    device count) BEFORE importing jax and to ``print(json.dumps(...))`` as
    its final line; everything before that line is free-form progress
    output.  Any nonzero exit fails the calling test with the subprocess
    stderr tail.
    """
    env = dict(os.environ)
    src = str(REPO_ROOT / "src")
    env["PYTHONPATH"] = (
        src + os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else src
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, timeout=timeout,
        cwd=REPO_ROOT,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.fixture
def forced_subprocess_json():
    """The shared forced-device subprocess runner, as a fixture."""
    return run_forced_device_subprocess


def _install_hypothesis_fallback() -> None:
    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    def integers(min_value=0, max_value=0):
        lo, hi = int(min_value), int(max_value)
        mid = lo + (hi - lo) // 2
        return _Strategy(dict.fromkeys([lo, mid, hi]))  # ordered unique

    def sampled_from(elements):
        elements = list(elements)
        picks = [elements[0], elements[len(elements) // 2], elements[-1]]
        out, seen = [], set()
        for p in picks:
            marker = id(p) if not isinstance(p, (int, float, str, bool, tuple)) else p
            if marker not in seen:
                seen.add(marker)
                out.append(p)
        return _Strategy(out)

    def given(*args, **strategies_kw):
        if args:
            raise TypeError("fallback hypothesis.given supports keyword strategies only")

        def deco(fn):
            names = list(strategies_kw)
            pools = [strategies_kw[n].examples for n in names]

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                # diagonal sampling, NOT a truncated cartesian product: every
                # strategy's full example set (both boundaries) is exercised
                # even when several strategies are combined.
                n = max((len(p) for p in pools), default=0)
                n = min(max(n, 1), _MAX_FALLBACK_EXAMPLES)
                for i in range(n):
                    combo = {
                        name: pool[i % len(pool)]
                        for name, pool in zip(names, pools)
                    }
                    fn(*a, **kw, **combo)

            # pytest resolves fixture needs via inspect.signature, which
            # follows __wrapped__ back to the strategy-parameterized original;
            # drop it so the wrapper presents a no-fixture (*a, **kw) signature
            # exactly like real hypothesis does.
            del wrapper.__wrapped__
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    def settings(*_a, **_kw):
        def deco(fn):
            return fn

        return deco

    def assume(condition):
        return bool(condition)

    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st
    hyp.HealthCheck = types.SimpleNamespace(too_slow="too_slow")
    hyp.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:  # pragma: no cover - trivially environment-dependent
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover
    _install_hypothesis_fallback()

"""repro.chaos: FaultPlan determinism, schedules, poison helpers, halo seam."""
import json

import pytest

import jax.numpy as jnp

from repro.chaos import (
    NULL_FAULT_PLAN,
    SITE_ACTIONS,
    SITES,
    FaultPlan,
    FaultSpec,
    corrupt_ghosts,
    poison_array,
    storm,
)

pytestmark = pytest.mark.chaos


def _drive(plan: FaultPlan, schedule):
    """Ask the plan per (site, n_asks) schedule; return the fired log."""
    for site, n in schedule:
        for _ in range(n):
            plan.ask(site)
    return plan.log()


# -- determinism ---------------------------------------------------------------


def test_same_seed_reproduces_fault_log():
    sites = {
        "dispatch": FaultSpec(probability=0.5, actions=("fail", "delay")),
        "kernel": FaultSpec(probability=0.4, actions=("nan", "inf")),
    }
    schedule = [("dispatch", 7), ("kernel", 5), ("dispatch", 3), ("kernel", 9)]
    log1 = _drive(FaultPlan(11, sites), schedule)
    log2 = _drive(FaultPlan(11, sites), schedule)
    assert log1 == log2 and len(log1) > 0


def test_site_streams_independent_of_interleaving():
    # the per-site (action, site_seq) sequence depends only on that site's
    # ask count — the property that makes a serving-stack storm replayable
    sites = {
        "dispatch": FaultSpec(probability=0.5, actions=("fail", "delay")),
        "kernel": FaultSpec(probability=0.5, actions=("nan", "inf")),
    }
    blocked = _drive(FaultPlan(3, sites), [("dispatch", 10), ("kernel", 10)])
    inter = FaultPlan(3, sites)
    for _ in range(10):
        inter.ask("dispatch")
        inter.ask("kernel")
    by_site = lambda log: {  # noqa: E731
        s: [(e["action"], e["site_seq"]) for e in log if e["site"] == s]
        for s in ("dispatch", "kernel")
    }
    assert by_site(blocked) == by_site(inter.log())


def test_different_seed_differs():
    sites = {"dispatch": FaultSpec(probability=0.5, actions=("fail",))}
    schedule = [("dispatch", 64)]
    assert _drive(FaultPlan(0, sites), schedule) != _drive(
        FaultPlan(1, sites), schedule)


def test_reset_rebuilds_the_identical_plan():
    plan = storm(9, dispatch_p=0.6, kernel_p=0.6)
    log1 = _drive(plan, [("dispatch", 8), ("kernel", 8)])
    again = plan.reset()
    assert again.seed == plan.seed and again.specs == plan.specs
    assert _drive(again, [("dispatch", 8), ("kernel", 8)]) == log1


# -- schedules -----------------------------------------------------------------


def test_after_and_max_fires_bound_the_storm():
    plan = FaultPlan(0, {"dispatch": FaultSpec(
        probability=1.0, actions=("fail",), after=2, max_fires=3)})
    fired = [plan.ask("dispatch") is not None for _ in range(10)]
    # never in the first `after` asks, then exactly max_fires, then silence
    assert fired == [False, False, True, True, True] + [False] * 5
    assert plan.fired == 3
    assert plan.fired_by_site() == {"dispatch": 3}
    assert [f["site_seq"] for f in plan.log()] == [2, 3, 4]


def test_delay_action_carries_delay_seconds():
    plan = FaultPlan(0, {"dispatch": FaultSpec(
        probability=1.0, actions=("delay",), delay_s=0.25)})
    f = plan.ask("dispatch", host=3)
    assert f.action == "delay" and f.delay_s == 0.25
    assert dict(f.ctx) == {"host": 3}


def test_unknown_site_and_action_rejected():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan(0, {"gpu": FaultSpec(probability=0.5)})
    with pytest.raises(ValueError, match="does not support actions"):
        FaultPlan(0, {"kernel": FaultSpec(probability=0.5, actions=("drop",))})
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(probability=1.5)


def test_disabled_plan_never_fires_and_never_draws():
    assert not NULL_FAULT_PLAN.enabled
    assert NULL_FAULT_PLAN.ask("dispatch") is None
    assert NULL_FAULT_PLAN.fired == 0
    # a plan whose sites all have probability 0 is dead too — the hot-path
    # guard `if faults.enabled` stays one always-false branch
    dead = FaultPlan(0, {"kernel": FaultSpec(probability=0.0)})
    assert not dead.enabled and dead.ask("kernel") is None


def test_describe_is_json_round_trippable_provenance():
    plan = storm(5, dispatch_p=0.3, halo_p=0.2, kernel_p=0.1, pool_p=0.4,
                 after=1, max_fires=2)
    desc = json.loads(json.dumps(plan.describe()))
    assert desc["seed"] == 5
    assert set(desc["sites"]) == {"dispatch", "halo", "kernel", "pool"}
    assert desc["sites"]["halo"]["actions"] == list(SITE_ACTIONS["halo"])
    assert desc["sites"]["dispatch"]["max_fires"] == 2


def test_storm_builder_skips_zero_probability_sites():
    plan = storm(0, kernel_p=0.5)
    assert set(plan.specs) == {"kernel"}
    assert set(SITE_ACTIONS) == set(SITES)


# -- poison helpers ------------------------------------------------------------


def test_poison_array_nan_and_inf_hit_one_fixed_element():
    x = jnp.ones((3, 4), jnp.complex64)
    for action, pred in (("nan", jnp.isnan), ("inf", jnp.isinf)):
        bad = poison_array(x, action)
        assert bad.shape == x.shape and bad.dtype == x.dtype
        flat = jnp.ravel(bad)
        assert bool(pred(jnp.real(flat[0])))
        assert bool(jnp.all(flat[1:] == 1.0))


def test_corrupt_ghosts_drop_zeroes_and_corrupt_nans():
    ghosts = (jnp.ones((2, 3)), jnp.full((4,), 2.0))
    dropped = corrupt_ghosts(ghosts, "drop")
    assert all(bool(jnp.all(g == 0)) for g in dropped)
    assert [g.shape for g in dropped] == [g.shape for g in ghosts]
    mangled = corrupt_ghosts(ghosts, "corrupt")
    assert all(bool(jnp.all(jnp.isnan(g))) for g in mangled)


# -- the plan-level halo seam (needs a real multi-host boundary) ---------------


_HALO_SEAM_CODE = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
import jax.numpy as jnp
from repro.core.su3.plan import EngineConfig, build_plan
from repro.launch.mesh import MeshSpec
from repro.chaos import FaultPlan, FaultSpec

plan = build_plan(EngineConfig(L=2, tile=16, iterations=1, warmups=0),
                  MeshSpec(hosts=2, devices_per_host=1))
u, v = plan.init_stencil_data()
step = plan.stencil_step(overlap=True)
clean = step(u, v)

plan.faults = FaultPlan(7, {"halo": FaultSpec(probability=1.0, actions=("drop",))})
dropped = step(u, v)
fired_drop = plan.faults.fired

plan.faults = FaultPlan(7, {"halo": FaultSpec(probability=1.0, actions=("corrupt",))})
corrupted = step(u, v)

from repro.chaos import NULL_FAULT_PLAN
plan.faults = NULL_FAULT_PLAN
clean_again = step(u, v)

print(json.dumps({
    "fired_drop": fired_drop,
    "drop_changes_boundary": not bool(jnp.array_equal(clean, dropped)),
    "corrupt_non_finite": not bool(jnp.all(jnp.isfinite(jnp.real(corrupted)))),
    "clean_path_bitwise_restored": bool(jnp.array_equal(clean, clean_again)),
}))
"""


def test_halo_fault_corrupts_only_faulted_steps(forced_subprocess_json):
    out = forced_subprocess_json(_HALO_SEAM_CODE)
    assert out["fired_drop"] == 1
    assert out["drop_changes_boundary"] is True
    assert out["corrupt_non_finite"] is True
    assert out["clean_path_bitwise_restored"] is True

"""LayoutCodec property tests: canonical <-> physical round-trips across
layout x word-dtype x tile, including site counts that do not divide the
AoSoA lane (the padding path) and bf16-storage round-trip tolerance.

Runs under real hypothesis when installed, and under the deterministic
conftest fallback (boundary + interior examples) otherwise.
"""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.su3 import layouts
from repro.core.su3.layouts import Layout


def _canonical(n_sites: int, seed: int) -> jax.Array:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n_sites, 4, 3, 3, 2)).astype(np.float32)
    return jnp.asarray(a[..., 0] + 1j * a[..., 1], jnp.complex64)


# bf16 has 8 mantissa bits: a standard-normal value rounds within ~2^-8 of
# itself relatively; 1e-2 absolute covers the [-4, 4] bulk with margin.
_TOL = {"float32": 0.0, "bfloat16": 4e-2}


@hypothesis.settings(deadline=None, max_examples=12)
@hypothesis.given(
    layout=st.sampled_from([Layout.AOS, Layout.SOA, Layout.AOSOA]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    tile=st.sampled_from([8, 16, 128]),
    n_sites=st.sampled_from([16, 81, 130, 256]),  # 81, 130: not lane multiples
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_pack_unpack_roundtrip(layout, dtype, tile, n_sites, seed):
    codec = layouts.make_codec(layout, tile=tile, dtype=dtype)
    a = _canonical(n_sites, seed)
    phys = codec.pack(a)
    assert phys.dtype == codec.word_dtype
    back = codec.unpack(phys, n_sites)
    assert back.shape == a.shape and back.dtype == a.dtype
    tol = _TOL[dtype]
    if tol == 0.0:
        assert bool(jnp.all(back == a)), "f32 round-trip must be exact"
    else:
        err = float(jnp.max(jnp.abs(back - a)))
        rel = err / max(float(jnp.max(jnp.abs(a))), 1.0)
        assert rel < tol, f"bf16 round-trip rel err {rel}"


@hypothesis.settings(deadline=None, max_examples=8)
@hypothesis.given(
    layout=st.sampled_from([Layout.SOA, Layout.AOSOA]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    tile=st.sampled_from([8, 32]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_planar_view_roundtrip_preserves_sites_and_dtype(layout, dtype, tile, seed):
    """planar_view / from_planar_view must be a pure reshape: zero-copy
    semantics, same dtype, exact values, site order consistent with pack."""
    n_sites = 4 * tile
    codec = layouts.make_codec(layout, tile=tile, dtype=dtype)
    a = _canonical(n_sites, seed)
    phys = codec.pack(a)
    view = codec.planar_view(phys)
    assert view.shape == (2, layouts.PLANAR_ROWS, n_sites)
    assert view.dtype == phys.dtype
    back = codec.from_planar_view(view, phys)
    assert back.shape == phys.shape
    assert bool(jnp.all(back == phys))


@hypothesis.settings(deadline=None, max_examples=6)
@hypothesis.given(
    n_sites=st.sampled_from([1, 7, 129]),  # all straddle the 128 lane
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_aosoa_padding_path_zero_fills_and_slices(n_sites, seed):
    """Site counts that do not divide the lane pad with zeros on pack and
    slice back to the live sites on unpack."""
    codec = layouts.make_codec(Layout.AOSOA, tile=128)
    a = _canonical(n_sites, seed)
    phys = codec.pack(a)
    padded = phys.shape[0] * codec.tile
    assert padded == ((n_sites + 127) // 128) * 128
    # the pad region is zeros (it streams through kernels harmlessly)
    full = codec.unpack(phys)  # no slice: padded length
    assert full.shape[0] == padded
    assert bool(jnp.all(full[n_sites:] == 0))
    assert bool(jnp.all(codec.unpack(phys, n_sites) == a))


def test_b_roundtrip_all_dtypes():
    for dtype in ("float32", "bfloat16"):
        codec = layouts.make_codec(Layout.SOA, dtype=dtype)
        b = _canonical(1, 3)[0]  # (4, 3, 3) complex
        b_p = codec.pack_b(b)
        assert b_p.shape == (2, layouts.PLANAR_ROWS)
        assert b_p.dtype == codec.word_dtype
        back = codec.unpack_b(b_p)
        if dtype == "float32":
            assert bool(jnp.all(back == b))
        else:
            assert float(jnp.max(jnp.abs(back - b))) < 4e-2


# -- two-row compressed codec -------------------------------------------------


def _su3(n_sites: int, seed: int) -> np.ndarray:
    """Random SU(3) links (n_sites, 4, 3, 3) complex128: QR orthonormalizes,
    the principal cube root of det rotates U(3) -> SU(3)."""
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((n_sites, 4, 3, 3)) + 1j * rng.standard_normal(
        (n_sites, 4, 3, 3))
    q, r = np.linalg.qr(g)
    # fix the QR phase ambiguity, then divide out the residual determinant
    q = q * (np.diagonal(r, axis1=-2, axis2=-1)
             / np.abs(np.diagonal(r, axis1=-2, axis2=-1)))[..., None, :]
    q = q / np.linalg.det(q)[..., None, None] ** (1.0 / 3.0)
    return q


def _nearest_su3(a: np.ndarray) -> np.ndarray:
    """SVD polar projection to U(3), det-normalized to SU(3)."""
    w, _s, vh = np.linalg.svd(a)
    p = w @ vh
    return p / np.linalg.det(p)[..., None, None] ** (1.0 / 3.0)


# stored rows round-trip at storage precision (f32 exact); the RECONSTRUCTED
# third row additionally pays the f64->storage rounding of rows 0/1 amplified
# through the cross product — a few ulp at f32, bf16-mantissa-sized at bf16.
_COMP_TOL = {"float32": 1e-5, "bfloat16": 6e-2}


@hypothesis.settings(deadline=None, max_examples=12)
@hypothesis.given(
    layout=st.sampled_from([Layout.SOA, Layout.AOSOA]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    tile=st.sampled_from([8, 16, 128]),
    n_sites=st.sampled_from([16, 81, 130]),  # 81, 130: padding path
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_compressed_roundtrip_reconstructs_su3_row2(
        layout, dtype, tile, n_sites, seed):
    """TWO_ROW pack stores 24 planar rows; unpack rebuilds row 2 within the
    storage-precision tolerance on genuine SU(3) input, and the two STORED
    rows round-trip exactly at f32 (they never left storage)."""
    codec = layouts.make_codec(layout, tile=tile, dtype=dtype,
                               compression="two_row")
    u = _su3(n_sites, seed)
    a = jnp.asarray(u, jnp.complex64)
    phys = codec.pack(a)
    assert phys.dtype == codec.word_dtype
    if layout == Layout.SOA:
        assert phys.shape == (2, layouts.PLANAR_COMP_ROWS, n_sites)
    else:
        padded = ((n_sites + tile - 1) // tile) * tile
        assert phys.shape == (padded // tile, 2, layouts.PLANAR_COMP_ROWS, tile)
    back = codec.unpack(phys, n_sites)
    assert back.shape == a.shape and back.dtype == a.dtype
    if dtype == "float32":
        assert bool(jnp.all(back[:, :, :2, :] == a[:, :, :2, :])), \
            "stored rows must round-trip exactly at f32"
    err = float(jnp.max(jnp.abs(back - jnp.asarray(u, jnp.complex64))))
    assert err < _COMP_TOL[dtype], f"row-2 reconstruction err {err}"


@hypothesis.settings(deadline=None, max_examples=8)
@hypothesis.given(
    eps=st.sampled_from([1e-3, 1e-2, 1e-1]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_compressed_reconstruction_error_bounded_by_unitarity_violation(
        eps, seed):
    """Off the SU(3) manifold the codec is lossy BY THE SAME ORDER as the
    input's own distance from SU(3): |unpack(pack(A)) - A| on row 2 is
    bounded by a generous constant times |A - nearest_SU3(A)|.  (On-manifold
    input is the eps -> 0 limit: both sides vanish.)"""
    rng = np.random.default_rng(seed)
    a = _su3(32, seed) + eps * (
        rng.standard_normal((32, 4, 3, 3))
        + 1j * rng.standard_normal((32, 4, 3, 3)))
    dist = float(np.max(np.linalg.norm(a - _nearest_su3(a), axis=(-2, -1))))
    codec = layouts.make_codec(Layout.SOA, compression="two_row")
    back = np.asarray(codec.unpack(codec.pack(jnp.asarray(a, jnp.complex64)), 32))
    err = float(np.max(np.abs(back[:, :, 2, :] - a[:, :, 2, :])))
    # C covers the cross-product's Lipschitz factor on O(1) rows, plus an
    # absolute f32 storage floor so the eps=1e-3 cases aren't noise-gated
    assert err <= 25.0 * dist + 1e-4, (err, dist)


def test_compressed_planar_view_roundtrip_and_aos_rejected():
    codec = layouts.make_codec(Layout.AOSOA, tile=8, compression="two_row")
    a = jnp.asarray(_su3(32, 7), jnp.complex64)
    phys = codec.pack(a)
    view = codec.planar_view(phys)
    assert view.shape == (2, layouts.PLANAR_COMP_ROWS, 32)
    assert bool(jnp.all(codec.from_planar_view(view, phys) == phys))
    with pytest.raises(ValueError, match="only defined for SOA/AoSoA"):
        layouts.make_codec(Layout.AOS, compression="two_row")


def test_aos_roundtrip_preserves_gauge_and_drops_metadata():
    """AOS carries 8 dead metadata words per site; unpack must return the
    gauge field untouched and ignore the metadata block."""
    codec = layouts.make_codec(Layout.AOS)
    a = _canonical(10, 4)
    phys = codec.pack(a)
    assert phys.shape == (10, layouts.SITE_WORDS_AOS)
    # metadata block: index words carry the site id (pack_aos contract)
    assert bool(jnp.all(phys[:, layouts.GAUGE_WORDS] == jnp.arange(10)))
    assert bool(jnp.all(codec.unpack(phys, 10) == a))

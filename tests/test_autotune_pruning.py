"""Roofline-pruned autotune: candidate enumeration, three-term ranking, the
<= 50% measurement bill, and selection within 5% of the exhaustive sweep.

The acceptance test runs the REAL enumeration + three-term ranking on CPU
interpret; measurements are a deterministic function of the model prediction
with bounded (3%) multiplicative perturbation, so the within-5% assertion
pins the *selection quality of the pruner* rather than CPU timer noise.  A
separate end-to-end test runs real measurements on a tiny candidate set.
"""
import math

import numpy as np
import pytest

from repro.core import autotune, roofline


def test_enumerate_candidates_gates_on_vmem():
    # 32768-site tile: resident working set ~18.9 MiB > 16 MiB VMEM -> out
    cands = autotune.enumerate_candidates(tiles=(128, 32768), ks=(1, 2))
    assert {c.tile for c in cands} == {128}
    assert {c.fused_k for c in cands} == {1, 2}
    # a wider accumulate re-inflates the resident set past VMEM
    big = autotune.enumerate_candidates(tiles=(16384,), ks=(1,), dtype="float32")
    none = autotune.enumerate_candidates(
        tiles=(16384,), ks=(1,), dtype="float32", accum_dtype="float64")
    assert len(big) == 1 and len(none) == 0


def test_three_term_prediction_shape(monkeypatch):
    monkeypatch.setattr(
        autotune, "kernel_instruction_model",
        lambda dtype="float32", accum_dtype="", tile=256, compression="none": (100.0, 50.0),
    )
    p = autotune.predict_pipeline(autotune.PipelineCandidate(128, 4), L=4)
    assert set(p) >= {"compute_s", "memory_s", "issue_s", "bound_s",
                      "dominant", "predicted_gflops"}
    assert p["bound_s"] == max(p["compute_s"], p["memory_s"], p["issue_s"])
    # small-L quick mode is the paper's PIUMA regime: issue-bound
    assert p["dominant"] == "issue"
    # deeper chains amortize the dispatch + staging issue cost
    deeper = autotune.predict_pipeline(autotune.PipelineCandidate(128, 8), L=4)
    assert deeper["issue_s"] < p["issue_s"]


def test_kernel_instruction_model_from_lowered_mix():
    """The issue term is estimated from the LOWERED kernel's instruction mix:
    chain depth 2 must cost strictly more instructions per grid step than
    depth 1, and the decomposition must be non-degenerate."""
    base, per_mult = autotune.kernel_instruction_model(tile=64)
    assert per_mult >= 1.0
    assert base >= 0.0


def test_pruned_measures_at_most_half_and_lands_within_5pct(monkeypatch):
    """The PR's acceptance bar: measure <= 50% of the exhaustive candidate
    set; the selected config's measured GFLOPS within 5% of the exhaustive
    sweep's best."""
    monkeypatch.setattr(
        autotune, "kernel_instruction_model",
        lambda dtype="float32", accum_dtype="", tile=256, compression="none": (100.0, 50.0),
    )
    measured = []

    def deterministic_measure(cand):
        # bounded +-3% multiplicative perturbation of the model: measured
        # rank can locally disagree with predicted rank (what makes pruning
        # non-trivial) but never by enough to hide the winner outside the
        # measured half
        measured.append(cand)
        pred = autotune.predict_pipeline(cand, L=4)["predicted_gflops"]
        wiggle = 1.0 + 0.03 * math.sin(7.0 * cand.tile + 13.0 * cand.fused_k)
        return {"tile": cand.tile, "fused_k": cand.fused_k, "vmem_kib": 1,
                "measured_gflops": pred * wiggle, "verified": True}

    exhaustive = autotune.pipeline_sweep(
        L=4, prune=1.0, measure_fn=deterministic_measure)
    n_total = exhaustive["candidates_total"]
    assert exhaustive["candidates_measured"] == n_total == len(
        autotune.enumerate_candidates())
    best_exhaustive = max(r["measured_gflops"] for r in exhaustive["rows"])

    measured.clear()
    pruned = autotune.pipeline_sweep(
        L=4, prune=0.5, measure_fn=deterministic_measure)
    assert len(measured) == pruned["candidates_measured"]
    assert pruned["candidates_measured"] <= math.ceil(0.5 * n_total)
    best_pruned = max(r["measured_gflops"] for r in pruned["rows"])
    assert best_pruned >= 0.95 * best_exhaustive

    # measured rank genuinely disagrees with predicted rank somewhere (the
    # perturbation is doing its job — selection is by measurement, not model)
    rows = sorted(pruned["rows"], key=lambda r: r["predicted_rank"])
    measured_order = [r["measured_gflops"] for r in rows]
    assert measured_order != sorted(measured_order, reverse=True)


def test_pruned_best_config_end_to_end_real_measurements(tmp_path):
    """Real CPU-interpret measurements on a tiny candidate grid: the pruned
    flow measures the top half only, selects a VERIFIED config, and persists
    the pipeline provenance under the v2 key."""
    ran = []

    def real_measure_small(cand):
        ran.append(cand)
        return autotune.measure_candidate(cand, L=2)

    sweep = autotune.pipeline_sweep(
        L=2, prune=0.5, tiles=(16, 32), ks=(1, 2),
        measure_fn=real_measure_small)
    assert sweep["candidates_total"] == 4
    assert sweep["candidates_measured"] == 2 == len(ran)
    for row in sweep["rows"]:
        assert row["verified"], row
        assert row["measured_gflops"] > 0.0
        assert {"predicted_rank", "issue_s", "vmem_kib"} <= set(row)


def test_best_config_persists_pipeline_provenance(tmp_path, monkeypatch):
    monkeypatch.setattr(
        autotune, "kernel_instruction_model",
        lambda dtype="float32", accum_dtype="", tile=256, compression="none": (100.0, 50.0),
    )

    def stub(cand):
        return {"tile": cand.tile, "fused_k": cand.fused_k, "vmem_kib": 1,
                "measured_gflops": float(cand.tile * cand.fused_k),
                "verified": True}

    cfg = autotune.best_config(L=4, cache_directory=str(tmp_path),
                               measure_fn=stub)
    pipe = cfg["pipeline"]
    assert pipe["schema"] == autotune.SCHEMA_VERSION
    assert pipe["candidates_measured"] <= math.ceil(
        0.5 * pipe["candidates_total"])
    assert 0 <= pipe["predicted_rank"] < pipe["candidates_measured"]
    # served from cache with the provenance intact
    again = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert again["cached"] and again["pipeline"] == pipe

"""ExecutionPlan layer: placement equivalence, fused stepping, registry,
batched lattice serving, and the persistent autotune cache."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.su3 import layouts, plan, registry
from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.layouts import Layout
from repro.kernels import ref


def _random_lattice(key, n_sites):
    a = jax.random.normal(key, (n_sites, 4, 3, 3, 2))
    return jax.lax.complex(a[..., 0], a[..., 1])


def _random_b(key):
    b = jax.random.normal(key, (4, 3, 3, 2))
    return jax.lax.complex(b[..., 0], b[..., 1])


# -- placement-policy equivalence --------------------------------------------


@pytest.mark.parametrize("variant,layout", [("pallas", Layout.SOA), ("versionX", Layout.AOS)])
def test_placement_policies_bit_identical(variant, layout):
    """sharded / host_scatter / replicated must produce bit-identical verified C."""
    results = {}
    for placement in plan.PLACEMENTS:
        cfg = EngineConfig(L=4, layout=layout, variant=variant, placement=placement,
                           iterations=1, warmups=0, tile=128)
        p = plan.build_plan(cfg)
        a_phys, b_p, _, _ = p.init_data()
        c = p.step(a_phys, b_p)
        assert p.verify(c), placement
        results[placement] = np.asarray(jax.device_get(c))
    base = results["sharded"]
    for placement, arr in results.items():
        np.testing.assert_array_equal(arr, base, err_msg=placement)


# -- fused multi-iteration stepping ------------------------------------------


@pytest.mark.parametrize("variant,layout", [
    ("pallas", Layout.SOA), ("pallas", Layout.AOSOA), ("versionX", Layout.SOA),
])
@pytest.mark.parametrize("k", [2, 4, 12])  # 12 exercises the fori_loop (>_UNROLL_MAX) path
def test_fused_step_matches_k_sequential(variant, layout, k):
    cfg = EngineConfig(L=2, layout=layout, variant=variant, tile=16,
                       iterations=1, warmups=0)
    p = plan.build_plan(cfg)
    codec = p.codec
    a = _random_lattice(jax.random.PRNGKey(3), p.padded_sites)
    b = _random_b(jax.random.PRNGKey(4))
    a_phys, b_p = codec.pack(a), codec.pack_b(b)
    x = a_phys
    for _ in range(k):
        x = p.step(x, b_p)
    fused = p.fused_step(k)(a_phys, b_p)
    np.testing.assert_allclose(
        np.asarray(jax.device_get(fused)), np.asarray(jax.device_get(x)),
        rtol=1e-5, atol=1e-5,
    )


def test_engine_run_fused_verifies():
    cfg = EngineConfig(L=4, iterations=3, warmups=1, tile=128)
    r = SU3Engine(cfg).run_fused(k=3)
    assert r.verified and r.fused_k == 3
    assert all(t > 0 for t in r.iter_seconds)


# -- registry + plan validation ----------------------------------------------


def test_registry_unifies_variants_and_pallas():
    names = registry.kernel_names()
    assert "pallas" in names and "versionX" in names and "version_gemm" in names
    entry = registry.get_kernel("pallas")
    assert entry.form == registry.PLANAR and entry.supports_fused
    assert registry.kernel_names(backend="pallas") == ["pallas"]
    assert "pallas" not in registry.kernel_names(form=registry.CANONICAL)


def test_plan_rejects_invalid_combinations():
    with pytest.raises(ValueError, match="layout"):
        plan.build_plan(EngineConfig(L=2, layout=Layout.AOS, variant="pallas", tile=16))
    with pytest.raises(KeyError, match="unknown SU3 kernel"):
        plan.build_plan(EngineConfig(L=2, variant="nope", tile=16))
    with pytest.raises(ValueError, match="placement"):
        plan.build_plan(EngineConfig(L=2, tile=16, placement="socket0"))


def test_codec_dedups_unpack_paths():
    """One codec handles padded and sliced unpack for every layout."""
    for layout in Layout:
        codec = layouts.make_codec(layout, tile=16)
        a = _random_lattice(jax.random.PRNGKey(7), 32)
        phys = codec.pack(a)
        np.testing.assert_allclose(
            np.asarray(codec.unpack(phys, 30)), np.asarray(a[:30]), atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(codec.unpack(phys)), np.asarray(a), atol=1e-6)


# -- batched lattice serving --------------------------------------------------


def test_batched_lattice_runner_matches_reference():
    runner = plan.BatchedLatticeRunner(EngineConfig(L=2, tile=16))
    B, S = 3, 16
    a = jnp.stack([_random_lattice(jax.random.PRNGKey(i), S) for i in range(B)])
    b = jnp.stack([_random_b(jax.random.PRNGKey(100 + i)) for i in range(B)])
    c = runner.multiply(a, b)
    for i in range(B):
        np.testing.assert_allclose(
            np.asarray(c[i]), np.asarray(ref.su3_mult_ref(a[i], b[i])),
            rtol=1e-4, atol=1e-4,
        )


def test_batched_lattice_runner_fused_chain():
    runner = plan.BatchedLatticeRunner(EngineConfig(L=2, tile=16))
    B, S = 2, 16
    a = jnp.stack([_random_lattice(jax.random.PRNGKey(i), S) for i in range(B)])
    b = jnp.stack([_random_b(jax.random.PRNGKey(50 + i)) for i in range(B)])
    fused = runner.multiply(a, b, k=3)
    seq = a
    for _ in range(3):
        seq = jnp.stack([ref.su3_mult_ref(seq[i], b[i]) for i in range(B)])
    np.testing.assert_allclose(np.asarray(fused), np.asarray(seq), rtol=1e-4, atol=1e-4)


# -- persistent autotune cache ------------------------------------------------


def test_best_config_roundtrips_through_cache(tmp_path, monkeypatch):
    calls = {"n": 0}
    real_sweep = autotune.tile_sweep

    def counting_sweep(*a, **kw):
        calls["n"] += 1
        return [
            {"tile": 128, "vmem_kib": 36, "fits_vmem": True,
             "measured_gflops": 2.0, "verified": True},
            {"tile": 4096, "vmem_kib": 1154, "fits_vmem": True,
             "measured_gflops": 1.0, "verified": True},
        ]

    monkeypatch.setattr(autotune, "tile_sweep", counting_sweep)
    first = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert calls["n"] == 1
    # measured winner, NOT the largest fitting tile
    assert first["tile"] == 128 and first["cached"] is False
    second = autotune.best_config(L=4, cache_directory=str(tmp_path))
    assert calls["n"] == 1, "second call must do zero measurements"
    assert second["tile"] == 128 and second["cached"] is True
    # refresh forces a re-measure
    autotune.best_config(L=4, cache_directory=str(tmp_path), refresh=True)
    assert calls["n"] == 2
    # tuned_engine_config flows the cached tuple into an EngineConfig
    cfg = autotune.tuned_engine_config(L=4, cache_directory=str(tmp_path), iterations=1)
    assert cfg.tile == 128 and cfg.variant == "pallas" and cfg.layout == Layout.SOA
    assert calls["n"] == 2
    autotune.tile_sweep = real_sweep  # belt-and-braces; monkeypatch also restores


def test_cache_key_identity():
    k = autotune.cache_key(backend="tpu", device_kind="v5e", layout="soa",
                           dtype="bfloat16", L=16, n_devices=4)
    assert k == "tpu|v5e|soa|bfloat16|L16|d4"

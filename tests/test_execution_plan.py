"""ExecutionPlan layer: placement equivalence, fused stepping, registry,
batched lattice serving, and the persistent autotune cache."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.su3 import layouts, plan, registry
from repro.core.su3.engine import EngineConfig, SU3Engine
from repro.core.su3.layouts import Layout
from repro.kernels import ref


def _random_lattice(key, n_sites):
    a = jax.random.normal(key, (n_sites, 4, 3, 3, 2))
    return jax.lax.complex(a[..., 0], a[..., 1])


def _random_b(key):
    b = jax.random.normal(key, (4, 3, 3, 2))
    return jax.lax.complex(b[..., 0], b[..., 1])


# -- placement-policy equivalence --------------------------------------------


@pytest.mark.parametrize("variant,layout", [("pallas", Layout.SOA), ("versionX", Layout.AOS)])
def test_placement_policies_bit_identical(variant, layout):
    """sharded / host_scatter / replicated must produce bit-identical verified C."""
    results = {}
    for placement in plan.PLACEMENTS:
        cfg = EngineConfig(L=4, layout=layout, variant=variant, placement=placement,
                           iterations=1, warmups=0, tile=128)
        p = plan.build_plan(cfg)
        a_phys, b_p, _, _ = p.init_data()
        c = p.step(a_phys, b_p)
        assert p.verify(c), placement
        results[placement] = np.asarray(jax.device_get(c))
    base = results["sharded"]
    for placement, arr in results.items():
        np.testing.assert_array_equal(arr, base, err_msg=placement)


# -- fused multi-iteration stepping ------------------------------------------


@pytest.mark.parametrize("variant,layout", [
    ("pallas", Layout.SOA), ("pallas", Layout.AOSOA), ("versionX", Layout.SOA),
])
@pytest.mark.parametrize("k", [2, 4, 12])  # 12 exercises the fori_loop (>_UNROLL_MAX) path
def test_fused_step_matches_k_sequential(variant, layout, k):
    cfg = EngineConfig(L=2, layout=layout, variant=variant, tile=16,
                       iterations=1, warmups=0)
    p = plan.build_plan(cfg)
    codec = p.codec
    a = _random_lattice(jax.random.PRNGKey(3), p.padded_sites)
    b = _random_b(jax.random.PRNGKey(4))
    a_phys, b_p = codec.pack(a), codec.pack_b(b)
    x = a_phys
    for _ in range(k):
        x = p.step(x, b_p)
    fused = p.fused_step(k)(a_phys, b_p)
    np.testing.assert_allclose(
        np.asarray(jax.device_get(fused)), np.asarray(jax.device_get(x)),
        rtol=1e-5, atol=1e-5,
    )


def test_engine_run_fused_verifies():
    cfg = EngineConfig(L=4, iterations=3, warmups=1, tile=128)
    r = SU3Engine(cfg).run_fused(k=3)
    assert r.verified and r.fused_k == 3
    assert all(t > 0 for t in r.iter_seconds)


# -- registry + plan validation ----------------------------------------------


def test_registry_unifies_variants_and_pallas():
    names = registry.kernel_names()
    assert "pallas" in names and "versionX" in names and "version_gemm" in names
    entry = registry.get_kernel("pallas")
    assert entry.form == registry.PLANAR and entry.supports_fused
    assert registry.kernel_names(backend="pallas") == [
        "pallas", "pallas_cg", "pallas_megakernel", "pallas_stencil"]
    assert "pallas" not in registry.kernel_names(form=registry.CANONICAL)
    assert registry.kernel_names(form=registry.BATCHED) == ["pallas_megakernel"]
    assert registry.kernel_names(form=registry.STENCIL) == ["pallas_stencil"]
    assert registry.kernel_names(form=registry.STENCIL_AXPY) == ["pallas_cg"]


def test_plan_rejects_invalid_combinations():
    with pytest.raises(ValueError, match="layout"):
        plan.build_plan(EngineConfig(L=2, layout=Layout.AOS, variant="pallas", tile=16))
    with pytest.raises(KeyError, match="unknown SU3 kernel"):
        plan.build_plan(EngineConfig(L=2, variant="nope", tile=16))
    with pytest.raises(ValueError, match="placement"):
        plan.build_plan(EngineConfig(L=2, tile=16, placement="socket0"))


def test_codec_dedups_unpack_paths():
    """One codec handles padded and sliced unpack for every layout."""
    for layout in Layout:
        codec = layouts.make_codec(layout, tile=16)
        a = _random_lattice(jax.random.PRNGKey(7), 32)
        phys = codec.pack(a)
        np.testing.assert_allclose(
            np.asarray(codec.unpack(phys, 30)), np.asarray(a[:30]), atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(codec.unpack(phys)), np.asarray(a), atol=1e-6)


# -- batched lattice serving --------------------------------------------------


def test_batched_lattice_runner_matches_reference():
    runner = plan.BatchedLatticeRunner(EngineConfig(L=2, tile=16))
    B, S = 3, 16
    a = jnp.stack([_random_lattice(jax.random.PRNGKey(i), S) for i in range(B)])
    b = jnp.stack([_random_b(jax.random.PRNGKey(100 + i)) for i in range(B)])
    c = runner.multiply(a, b)
    for i in range(B):
        np.testing.assert_allclose(
            np.asarray(c[i]), np.asarray(ref.su3_mult_ref(a[i], b[i])),
            rtol=1e-4, atol=1e-4,
        )


def test_batched_lattice_runner_fused_chain():
    runner = plan.BatchedLatticeRunner(EngineConfig(L=2, tile=16))
    B, S = 2, 16
    a = jnp.stack([_random_lattice(jax.random.PRNGKey(i), S) for i in range(B)])
    b = jnp.stack([_random_b(jax.random.PRNGKey(50 + i)) for i in range(B)])
    fused = runner.multiply(a, b, k=3)
    seq = a
    for _ in range(3):
        seq = jnp.stack([ref.su3_mult_ref(seq[i], b[i]) for i in range(B)])
    np.testing.assert_allclose(np.asarray(fused), np.asarray(seq), rtol=1e-4, atol=1e-4)


# -- mixed-precision (bf16-storage / f32-accumulate) plans ---------------------
# (persistent autotune cache coverage lives in tests/test_autotune_cache.py)


def test_bf16_accum_plan_matches_f32_and_verifies():
    a = _random_lattice(jax.random.PRNGKey(11), 16)
    b = _random_b(jax.random.PRNGKey(12))
    p32 = plan.build_plan(EngineConfig(L=2, tile=16))
    p16 = plan.build_plan(
        EngineConfig(L=2, tile=16, dtype="bfloat16", accum_dtype="float32")
    )
    c32 = np.asarray(p32.codec.unpack(p32.step(p32.codec.pack(a), p32.codec.pack_b(b))))
    c16 = np.asarray(p16.codec.unpack(p16.step(p16.codec.pack(a), p16.codec.pack_b(b))))
    rel = np.max(np.abs(c16 - c32)) / np.max(np.abs(c32))
    assert rel < 1e-2  # storage rounding only; the FMA chain accumulated in f32
    # canonical verification + fused chain through the mixed plan
    a_phys, b_p, _, _ = p16.init_data()
    assert p16.verify(p16.step(a_phys, b_p))
    assert p16.verify(p16.fused_step(3)(a_phys, b_p))
    assert p16.cfg.is_mixed_precision and p16.cfg.word_bytes == 2


def test_mixed_precision_requires_kernel_accum_support():
    name = "_planar_no_accum_test"
    registry.register_kernel(
        name, layouts=(Layout.SOA,), backends=("pallas",),
        form=registry.PLANAR, supports_fused=True,
    )(lambda a_p, b_p, **kw: a_p)
    try:
        with pytest.raises(ValueError, match="accumulate"):
            plan.build_plan(EngineConfig(
                L=2, tile=16, variant=name,
                dtype="bfloat16", accum_dtype="float32",
            ))
    finally:
        registry._KERNELS.pop(name, None)
    # canonical kernels accumulate in f32 by construction: no error
    p = plan.build_plan(EngineConfig(
        L=2, tile=16, variant="versionX",
        dtype="bfloat16", accum_dtype="float32",
    ))
    a_phys, b_p, _, _ = p.init_data()
    assert p.verify(p.step(a_phys, b_p))

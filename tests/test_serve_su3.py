"""Serving subsystem: dynamic batcher, SU3Service, metrics, bf16 plans."""
import asyncio

import numpy as np
import pytest

pytestmark = pytest.mark.slow  # smoke's fast tier skips these (-m "not slow")

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.su3.layouts import Layout
from repro.kernels import ref
from repro.serve.su3 import (
    BatcherConfig,
    DynamicBatcher,
    ServeRequest,
    ServiceConfig,
    ServiceMetrics,
    SU3Service,
)

S2 = 16  # L=2 lattice sites


def _rand_a(seed, n_sites=S2):
    a = jax.random.normal(jax.random.PRNGKey(seed), (n_sites, 4, 3, 3, 2))
    return jax.lax.complex(a[..., 0], a[..., 1])


def _rand_b(seed):
    b = jax.random.normal(jax.random.PRNGKey(seed), (4, 3, 3, 2))
    return jax.lax.complex(b[..., 0], b[..., 1])


def _req(i, L=2, k=1, arrival=0.0):
    return ServeRequest(req_id=i, a=None, b=None, L=L, k=k, arrival_s=arrival or i + 1.0)


def _svc(**kw):
    cfg = dict(autotune=False, tile=16)
    cfg.update(kw)
    return SU3Service(ServiceConfig(**cfg))


# -- batcher -------------------------------------------------------------------


def test_batcher_buckets_by_L_and_k():
    b = DynamicBatcher(BatcherConfig(max_batch=8, warm_batch_sizes=(1, 2, 4, 8)))
    for i, (L, k) in enumerate([(2, 1), (2, 2), (4, 1), (2, 1)]):
        assert b.submit(_req(i, L=L, k=k))
    assert len(b) == 4
    assert b.bucket_depths() == {(2, 1): 2, (2, 2): 1, (4, 1): 1}
    batch = b.next_batch()  # oldest head: req 0 in bucket (2, 1)
    assert batch.key == (2, 1) and [r.req_id for r in batch.requests] == [0, 3]
    assert len(b) == 2


def test_batcher_oldest_bucket_first_no_starvation():
    b = DynamicBatcher(BatcherConfig(max_batch=8, warm_batch_sizes=(1, 8)))
    b.submit(_req(0, L=4, k=1, arrival=1.0))
    b.submit(_req(1, L=2, k=1, arrival=2.0))
    b.submit(_req(2, L=4, k=1, arrival=3.0))
    assert b.next_batch().key == (4, 1)  # head req 0 is oldest
    assert b.next_batch().key == (2, 1)  # now req 1 is oldest


def test_batcher_pads_to_warm_size_and_reports_occupancy():
    cfg = BatcherConfig(max_batch=8, warm_batch_sizes=(1, 2, 4, 8))
    b = DynamicBatcher(cfg)
    for i in range(3):
        b.submit(_req(i))
    batch = b.next_batch()
    assert batch.padded_size == 4 and batch.pad == 1
    assert batch.occupancy == pytest.approx(0.75)
    assert cfg.padded_size(9) == 9  # past the largest warm size: exact


def test_batcher_max_batch_caps_coalescing():
    b = DynamicBatcher(BatcherConfig(max_batch=2, warm_batch_sizes=(1, 2)))
    for i in range(5):
        b.submit(_req(i))
    sizes = []
    while (batch := b.next_batch()) is not None:
        sizes.append(len(batch.requests))
    assert sizes == [2, 2, 1]


def test_batcher_backpressure():
    b = DynamicBatcher(BatcherConfig(max_queue_depth=2))
    assert b.submit(_req(0)) and b.submit(_req(1))
    assert not b.submit(_req(2))  # budget exhausted -> rejected
    b.next_batch()
    assert b.submit(_req(2))  # drained -> admits again


def test_batcher_config_validation():
    with pytest.raises(ValueError, match="max_batch"):
        BatcherConfig(max_batch=0)
    with pytest.raises(ValueError, match="max_queue_depth"):
        BatcherConfig(max_queue_depth=0)  # would reject every submit
    with pytest.raises(ValueError, match="warm_batch_sizes"):
        BatcherConfig(warm_batch_sizes=(4, 2))
    with pytest.raises(ValueError, match="largest warm batch"):
        BatcherConfig(max_batch=16, warm_batch_sizes=(1, 2, 4, 8))


# -- service -------------------------------------------------------------------


def test_service_results_match_reference_mixed_k():
    svc = _svc()
    reqs = []
    for i, k in enumerate([1, 2, 1, 3]):
        a, b = _rand_a(i), _rand_b(100 + i)
        reqs.append((svc.submit(a, b, k=k), a, b, k))
    assert svc.run_until_drained() == 4
    for rid, a, b, k in reqs:
        c = svc.pop_result(rid)
        expect = a
        for _ in range(k):
            expect = ref.su3_mult_ref(expect, b)
        np.testing.assert_allclose(
            np.asarray(c), np.asarray(expect), rtol=1e-4, atol=1e-4
        )


def test_service_coalesces_same_bucket_into_one_dispatch():
    svc = _svc()
    for i in range(4):
        svc.submit(_rand_a(i), _rand_b(i), k=1)
    svc.run_until_drained()
    snap = svc.metrics.snapshot()
    assert snap["dispatches"] == 1 and snap["mean_live_batch"] == 4.0
    assert snap["completed"] == 4


def test_service_backpressure_and_metrics():
    svc = _svc(batcher=BatcherConfig(max_queue_depth=2))
    a, b = _rand_a(0), _rand_b(0)
    assert svc.submit(a, b, k=1) is not None
    assert svc.submit(a, b, k=1) is not None
    assert svc.submit(a, b, k=1) is None  # backpressure
    svc.run_until_drained()
    snap = svc.metrics.snapshot()
    assert snap["rejected"] == 1 and snap["admitted"] == 2
    assert snap["queue_depth_max"] == 2


def test_service_rejects_malformed_lattice():
    svc = _svc()
    with pytest.raises(ValueError, match="canonical"):
        svc.submit(jnp.zeros((17, 4, 3, 3), jnp.complex64), _rand_b(0))


def test_service_config_rejects_non_planar_layout():
    with pytest.raises(ValueError, match="planar"):
        ServiceConfig(layout=Layout.AOS)
    # the autotune cache only holds SoA-measured tuples
    with pytest.raises(ValueError, match="SoA plans only"):
        ServiceConfig(layout=Layout.AOSOA, autotune=True)
    assert ServiceConfig(layout=Layout.AOSOA, autotune=False).layout == Layout.AOSOA


def test_service_pop_ready_drains_all_results():
    svc = _svc()
    ids = [svc.submit(_rand_a(i), _rand_b(i), k=1) for i in range(3)]
    svc.run_until_drained()
    ready = svc.pop_ready()
    assert sorted(ready) == sorted(ids)
    assert svc.pop_ready() == {}  # drained: nothing retained
    assert not any(svc.has_result(rid) for rid in ids)


def test_pop_ready_leaves_awaited_results_for_arun():
    """A poller draining via pop_ready must not steal an arun's result."""

    async def go():
        svc = _svc()
        pending = asyncio.ensure_future(svc.arun(_rand_a(0), _rand_b(0), k=1))
        drained = {}
        for _ in range(50):
            await asyncio.sleep(0)
            svc.step()
            drained.update(svc.pop_ready())
            if pending.done():
                break
        return await pending, drained

    c, drained = asyncio.run(go())
    assert drained == {}  # the awaited result was delivered by arun, not stolen
    np.testing.assert_allclose(
        np.asarray(c), np.asarray(ref.su3_mult_ref(_rand_a(0), _rand_b(0))),
        rtol=1e-4, atol=1e-4,
    )


def test_service_warm_precompiles_shapes():
    svc = _svc()
    svc.warm((2,), ks=(1,), batch_sizes=(4,))
    svc.metrics.reset()
    for i in range(4):
        svc.submit(_rand_a(i), _rand_b(i), k=1)
    svc.run_until_drained()
    assert svc.metrics.snapshot()["compiles"] == 0  # shape was warmed


def test_service_bf16_storage_within_1e2_of_f32():
    """The acceptance bar: bf16-storage/f32-accumulate vs the f32 path."""
    f32, bf16 = _svc(), _svc(dtype="bfloat16", accum_dtype="float32")
    pairs = [(_rand_a(i), _rand_b(50 + i)) for i in range(3)]
    ids32 = [f32.submit(a, b, k=2) for a, b in pairs]
    ids16 = [bf16.submit(a, b, k=2) for a, b in pairs]
    f32.run_until_drained()
    bf16.run_until_drained()
    for i32, i16 in zip(ids32, ids16):
        c32 = np.asarray(f32.pop_result(i32))
        c16 = np.asarray(bf16.pop_result(i16))
        rel = np.max(np.abs(c16 - c32)) / max(np.max(np.abs(c32)), 1.0)
        assert rel < 1e-2
    # the bf16 pool runs genuinely mixed-precision plans
    plan16 = bf16.runner_for(2).plan
    assert plan16.cfg.dtype == "bfloat16" and plan16.cfg.accum_dtype == "float32"
    assert "+acc-float32" in plan16.describe()


def test_bf16_plan_streams_fewer_hlo_bytes_than_f32():
    f32 = autotune.hlo_bytes_for_variant("pallas", Layout.SOA, n_sites=256, tile=64)
    bf16 = autotune.hlo_bytes_for_variant(
        "pallas", Layout.SOA, n_sites=256, tile=64,
        dtype="bfloat16", accum_dtype="float32",
    )
    assert bf16 < f32
    # canonical variants show the clean 2x storage drop
    xf32 = autotune.hlo_bytes_for_variant("versionX", Layout.SOA, n_sites=256, tile=64)
    xbf16 = autotune.hlo_bytes_for_variant(
        "versionX", Layout.SOA, n_sites=256, tile=64, dtype="bfloat16"
    )
    assert xbf16 < 0.92 * xf32


def test_service_async_face_coalesces():
    async def go():
        svc = _svc()
        outs = await asyncio.gather(
            *[svc.arun(_rand_a(i), _rand_b(i), k=1) for i in range(4)]
        )
        return svc, outs

    svc, outs = asyncio.run(go())
    assert len(outs) == 4
    assert svc.metrics.snapshot()["dispatches"] == 1  # one gather tick, one batch
    for i, c in enumerate(outs):
        np.testing.assert_allclose(
            np.asarray(c), np.asarray(ref.su3_mult_ref(_rand_a(i), _rand_b(i))),
            rtol=1e-4, atol=1e-4,
        )


# -- metrics -------------------------------------------------------------------


def test_metrics_snapshot_schema_and_percentiles():
    m = ServiceMetrics()
    for depth in (1, 2, 3):
        m.record_admit(depth)
    m.record_dispatch(live=3, padded=4, step_s=0.5, flops=864e6 * 3)
    for lat in (0.010, 0.020, 0.100):
        m.record_completion(lat)
    snap = m.snapshot()
    assert snap["admitted"] == 3 and snap["completed"] == 3
    assert snap["latency_p50_ms"] == pytest.approx(20.0)
    assert snap["latency_p99_ms"] == pytest.approx(100.0, rel=0.05)
    assert snap["mean_batch_occupancy"] == pytest.approx(0.75)
    assert snap["padded_slot_fraction"] == pytest.approx(0.25)
    assert snap["sustained_gflops_busy"] == pytest.approx(864e6 * 3 / 0.5 / 1e9)
    assert snap["queue_depth_max"] == 3
    m.reset()
    empty = m.snapshot()
    assert empty["completed"] == 0 and empty["latency_p99_ms"] == 0.0


# -- solve requests (data-dependent iteration count) ---------------------------


def _solve_problem(L=2):
    return autotune._cg_measure_problem(L)


def test_submit_solve_result_matches_reference():
    from repro.core.su3.plan import CG_SHIFT, cg_reference_solve

    svc = _svc(solve_iters_per_step=2)
    u, b = _solve_problem()
    rid = svc.submit_solve(u, b, tol=1e-6, max_iters=64)
    assert rid is not None
    svc.run_until_drained()
    x = svc.pop_result(rid)
    x_ref, _, ok = cg_reference_solve(u, b, 2, sigma=CG_SHIFT, tol=1e-6,
                                      max_iters=64)
    assert ok
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_ref),
                               rtol=1e-5, atol=1e-5)


def test_solve_validation_and_admit_metrics():
    svc = _svc()
    u, b = _solve_problem()
    with pytest.raises(ValueError, match="canonical"):
        svc.submit_solve(u, jnp.zeros((3,), jnp.complex64))
    with pytest.raises(ValueError, match="max_iters"):
        svc.submit_solve(u, b, max_iters=0)
    with pytest.raises(ValueError, match="tol"):
        svc.submit_solve(u, b, tol=-1.0)
    assert svc.submit_solve(u, b) is not None
    assert svc.metrics.snapshot()["admitted"] == 1
    svc.run_until_drained()


def test_solve_retires_midstream_and_frees_budget():
    """One long solve + a multiply stream on the same host: the rotation
    keeps multiplies completing WHILE the solve is in flight, the solve
    retires on its residual test (not max_iters), and a multiply submitted
    AFTER retirement is served immediately — the budget is free again."""
    svc = _svc(solve_iters_per_step=2)
    u, b = _solve_problem()
    sid = svc.submit_solve(u, b, tol=1e-6, max_iters=64)
    mids = [svc.submit(_rand_a(i), _rand_b(i), k=1) for i in range(3)]
    solve_done_at = None
    mult_done_mid_solve = 0
    steps = 0
    while svc.pending():
        steps += 1
        svc.step()
        for rid in list(svc.pop_ready()):
            if rid == sid:
                solve_done_at = steps
            elif solve_done_at is None:
                mult_done_mid_solve += 1
    assert solve_done_at is not None
    assert mult_done_mid_solve >= 1  # multiplies flowed during the solve
    snap = svc.metrics.snapshot()
    assert 0 < snap["kind_iterations"]["solve"] < 64  # retired early
    # the freed budget serves new traffic in one step
    rid = svc.submit(_rand_a(9), _rand_b(9), k=1)
    svc.step()
    assert rid in svc.pop_ready()


def test_solve_kind_rotation_non_starving():
    """All three kinds pending at once: the rotation serves each in turn,
    so every kind completes and none waits for the others to drain."""
    svc = _svc(solve_iters_per_step=1)
    u, b = _solve_problem()
    n = 16
    v = jax.random.normal(jax.random.PRNGKey(3), (n, 3, 2))
    sid = svc.submit_solve(u, b, tol=1e-6, max_iters=64)
    tid = svc.submit_stencil(u, jax.lax.complex(v[..., 0], v[..., 1]))
    mid = svc.submit(_rand_a(0), _rand_b(0), k=1)
    done_step: dict[int, int] = {}
    steps = 0
    while svc.pending():
        steps += 1
        svc.step()
        for rid in svc.pop_ready():
            done_step[rid] = steps
    assert set(done_step) == {sid, tid, mid}
    # with one solve iteration per turn the solve needs many turns; the
    # other kinds must NOT be starved behind it
    assert done_step[mid] < done_step[sid]
    assert done_step[tid] < done_step[sid]
    snap = svc.metrics.snapshot()
    assert snap["completed"] == 3
    # one iteration per turn: the iteration metric counts every solve turn
    assert snap["kind_iterations"]["solve"] >= 2


def test_solve_per_kind_iteration_metrics():
    svc = _svc(solve_iters_per_step=4)
    u, b = _solve_problem()
    svc.submit_solve(u, b, tol=1e-6, max_iters=64)
    svc.run_until_drained()
    snap = svc.metrics.snapshot()
    ki = snap["kind_iterations"]
    assert set(ki) == {"solve"} and ki["solve"] > 0
    assert ki["solve"] % 4 in (0, 1, 2, 3)  # dispatched in <=4-iteration turns
    assert snap["iterations"] >= ki["solve"]

"""Two-row compressed gauge: kernel/plan/engine level correctness.

The codec-level pack/unpack properties live in
``test_layout_codec_roundtrip.py``; here the compressed PATH is exercised —
the Pallas multiply / megakernel / stencil kernels streaming (2, 24, S)
gauge blocks with in-register third-row reconstruction — against the
18-real full-width kernels on the same canonical data.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.su3 import layouts, registry
from repro.core.su3.engine import EngineConfig as _EngineConfig  # noqa: F401
from repro.core.su3.engine import SU3Engine
from repro.core.su3.layouts import Layout
from repro.core.su3.plan import EngineConfig, build_plan, make_raw_step

_TILE = 32
_SITES = 64


def _su3(n_sites: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((n_sites, 4, 3, 3)) + 1j * rng.standard_normal(
        (n_sites, 4, 3, 3))
    q, r = np.linalg.qr(g)
    q = q * (np.diagonal(r, axis1=-2, axis2=-1)
             / np.abs(np.diagonal(r, axis1=-2, axis2=-1)))[..., None, :]
    return q / np.linalg.det(q)[..., None, None] ** (1.0 / 3.0)


def _steps(compression: str):
    codec = layouts.make_codec(Layout.SOA, tile=_TILE, compression=compression)
    step = make_raw_step(codec, registry.get_kernel("pallas"), tile=_TILE)
    return codec, step


def test_compressed_multiply_matches_full_kernel_on_su3():
    """C = A x B through the compressed kernel agrees with the full-width
    kernel to f32 reconstruction accuracy when A, B are genuine SU(3) (so
    the product rows the compressed path reconstructs are exact group
    elements)."""
    a = jnp.asarray(_su3(_SITES, 0), jnp.complex64)
    b = jnp.asarray(_su3(1, 1)[0], jnp.complex64)
    codec_f, step_f = _steps("none")
    codec_c, step_c = _steps("two_row")
    out_f = codec_f.unpack(step_f(codec_f.pack(a), codec_f.pack_b(b)), _SITES)
    out_c = codec_c.unpack(step_c(codec_c.pack(a), codec_c.pack_b(b)), _SITES)
    err = float(jnp.max(jnp.abs(out_c - out_f)))
    assert err < 1e-5, err
    # the STORED rows (0, 1) are the same FMA chain in both kernels — they
    # agree to ~ulp even off the group manifold (checked below)


def test_compressed_multiply_stored_rows_track_full_kernel_any_input():
    """Rows 0/1 of the compressed product never involve reconstruction on
    the OUTPUT side: for arbitrary (non-unitary) input they match the full
    kernel's rows 0/1 at f32 rounding — the compressed multiply's stored
    output is as exact as the full layout's."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((_SITES, 4, 3, 3))
                    + 1j * rng.standard_normal((_SITES, 4, 3, 3)),
                    jnp.complex64)
    b = jnp.asarray(rng.standard_normal((4, 3, 3))
                    + 1j * rng.standard_normal((4, 3, 3)), jnp.complex64)
    codec_f, step_f = _steps("none")
    codec_c, step_c = _steps("two_row")
    full_p = codec_f.planar_view(step_f(codec_f.pack(a), codec_f.pack_b(b)))
    comp_p = codec_c.planar_view(step_c(codec_c.pack(a), codec_c.pack_b(b)))
    rows = list(layouts.COMP_ROW_INDICES)
    scale = float(jnp.max(jnp.abs(full_p)))
    err = float(jnp.max(jnp.abs(comp_p - full_p[:, rows, :])))
    assert err <= 4e-6 * max(scale, 1.0), (err, scale)


def test_compressed_megakernel_chain_matches_dispatched_full_steps():
    """The slot-batched megakernel with ``compressed=True`` chains K
    compressed multiplies per slot in one dispatch; each slot must agree
    with K separately dispatched FULL-width steps on SU(3) data."""
    slot_k = (1, 3)
    a = jnp.asarray(_su3(_SITES, 3), jnp.complex64)
    b = jnp.asarray(_su3(1, 4)[0], jnp.complex64)
    codec_f, step_f = _steps("none")
    codec_c, _ = _steps("two_row")
    mk = registry.get_kernel("pallas_megakernel")
    a_c = jnp.stack([codec_c.pack(a)] * len(slot_k))
    b_p = jnp.stack([codec_c.pack_b(b)] * len(slot_k))
    out = mk.fn(a_c, b_p, jnp.asarray(slot_k, jnp.int32), tile=_TILE,
                compressed=True)
    for slot, k in enumerate(slot_k):
        ref_phys = codec_f.pack(a)
        for _ in range(k):
            ref_phys = step_f(ref_phys, codec_f.pack_b(b))
        ref = codec_f.unpack(ref_phys, _SITES)
        got = codec_c.unpack(out[slot], _SITES)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < k * 1e-5, (slot, k, err)


@pytest.mark.parametrize("dtype,accum", [("float32", ""),
                                         ("bfloat16", "float32")])
def test_compressed_engine_run_verifies_and_streams_two_thirds(dtype, accum):
    rows = {}
    for compression in ("none", "two_row"):
        cfg = EngineConfig(L=4, tile=64, dtype=dtype, accum_dtype=accum,
                           iterations=1, warmups=0, compression=compression)
        r = SU3Engine(cfg).run()
        assert r.verified, compression
        rows[compression] = r.row()
    assert rows["two_row"]["compression"] == "two_row"
    # 96 words/site vs 144: the whole tentpole in one ratio
    assert (rows["two_row"]["bytes_per_site"] * 3
            == rows["none"]["bytes_per_site"] * 2)


@pytest.mark.parametrize("compression", ["none", "two_row"])
def test_stencil_depth2_single_host_bit_identical(compression):
    """ONE depth-2 application == TWO depth-1 applications, bitwise — the
    single-host fast check of the communication-avoiding schedule (the
    1/2/4-host forced-device version runs in benchmarks/stencil.py and is
    gated by scripts/bench_diff.py)."""
    cfg = EngineConfig(L=4, tile=64, iterations=1, warmups=0,
                      compression=compression)
    plan = build_plan(cfg)
    u, v = plan.init_stencil_data()
    s1 = plan.stencil_step(overlap=False, depth=1)
    s2 = plan.stencil_step(overlap=False, depth=2)
    out1 = s1(u, v)
    assert plan.verify_stencil(out1), "depth-1 fixed point"
    assert bool(jnp.array_equal(s2(u, v), s1(u, out1)))


def test_compressed_stencil_bf16_storage_verifies():
    cfg = EngineConfig(L=4, tile=64, dtype="bfloat16", accum_dtype="float32",
                      iterations=1, warmups=0, compression="two_row")
    plan = build_plan(cfg)
    u, v = plan.init_stencil_data()
    out = plan.stencil_step(overlap=False)(u, v)
    assert plan.verify_stencil(out)

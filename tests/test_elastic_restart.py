"""Elastic restart integration: train on a 4-device mesh, 'lose' two
devices, re-plan the mesh with ElasticMeshPlanner, restore the checkpoint
with the new shardings, and continue training — loss continuity asserted.

Runs in subprocesses (device count locks at first jax init)."""
import os
import pathlib
import subprocess
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parents[1]

_PHASE1 = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro import compat
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import registry, common
from repro.distributed import sharding
from repro.launch.mesh import make_mesh
from repro.optim import adamw
from repro.train.train_step import make_train_step
from repro.data.pipeline import DataConfig, PipelineState, TokenPipeline, make_train_batch
from repro.checkpoint.manager import CheckpointManager, CheckpointConfig

ckpt_dir = sys.argv[1]
cfg = get_config("qwen3-4b").reduced()
mesh = make_mesh((2, 2), ("data", "model"))
rules = sharding.default_rules(mesh)
api = registry.get(cfg)
p_sh = sharding.param_shardings(api.spec(cfg), mesh, rules)
with compat.set_mesh(mesh):
    params = api.init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(jax.device_put, params, p_sh)
    opt_cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    opt = adamw.init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, q_chunk=8, kv_chunk=8))
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, 32, 4, seed=1))
    pstate = PipelineState()
    for _ in range(6):
        batch, pstate = make_train_batch(pipe, pstate, cfg)
        params, opt, m = step(params, opt, batch)
mgr = CheckpointManager(CheckpointConfig(ckpt_dir, async_save=False))
mgr.save(6, (params, opt), {"pipeline_step": pstate.step, "loss": float(m["loss"])})
print("PHASE1_LOSS", float(m["loss"]))
"""

_PHASE2 = r"""
import os, sys
# two of four hosts died -> planner gives a 2-device mesh
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import get_config
from repro.models import registry
from repro.distributed import sharding
from repro.distributed.fault_tolerance import ElasticMeshPlanner
from repro.launch.mesh import make_mesh
from repro.optim import adamw
from repro.train.train_step import make_train_step
from repro.data.pipeline import DataConfig, PipelineState, TokenPipeline, make_train_batch
from repro.checkpoint.manager import CheckpointManager, CheckpointConfig

ckpt_dir = sys.argv[1]
plan = ElasticMeshPlanner(devices_per_host=1, model_axis=2, global_batch=4).plan(
    alive_hosts=["h0", "h1"], dead_hosts=["h2", "h3"])
assert plan.n_devices == 2 and plan.model == 2, plan
mesh = make_mesh((plan.data, plan.model), ("data", "model"))
cfg = get_config("qwen3-4b").reduced()
api = registry.get(cfg)
rules = sharding.default_rules(mesh)
p_sh = sharding.param_shardings(api.spec(cfg), mesh, rules)

template_p = api.init(jax.random.PRNGKey(0), cfg)
opt_cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
template = (template_p, adamw.init(template_p, opt_cfg))
mgr = CheckpointManager(CheckpointConfig(ckpt_dir))
(params, opt), extra, start = mgr.restore(template)
# reshard onto the SURVIVOR mesh: host arrays -> new shardings
with compat.set_mesh(mesh):
    params = jax.tree.map(lambda x, s: jax.device_put(jnp.asarray(x), s), params, p_sh)
    opt = {"m": jax.tree.map(lambda x, s: jax.device_put(jnp.asarray(x), s), opt["m"], p_sh),
           "v": jax.tree.map(lambda x, s: jax.device_put(jnp.asarray(x), s), opt["v"], p_sh),
           "count": jnp.asarray(opt["count"])}
    step = jax.jit(make_train_step(cfg, opt_cfg, q_chunk=8, kv_chunk=8))
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, 32, 4, seed=1))
    pstate = PipelineState(step=int(extra["pipeline_step"]))
    losses = []
    for _ in range(4):
        batch, pstate = make_train_batch(pipe, pstate, cfg)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
prev = float(extra["loss"])
# continuity: restored training stays in the same loss regime (no re-init jump)
assert abs(losses[0] - prev) < 1.0, (losses[0], prev)
print("PHASE2_OK", prev, losses)
"""


def test_elastic_restart_after_failure():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    with tempfile.TemporaryDirectory() as d:
        p1 = subprocess.run([sys.executable, "-c", _PHASE1, d],
                            capture_output=True, text=True, env=env,
                            timeout=480, cwd=ROOT)
        assert p1.returncode == 0, p1.stderr[-2000:]
        assert "PHASE1_LOSS" in p1.stdout
        p2 = subprocess.run([sys.executable, "-c", _PHASE2, d],
                            capture_output=True, text=True, env=env,
                            timeout=480, cwd=ROOT)
        assert p2.returncode == 0, p2.stderr[-2000:]
        assert "PHASE2_OK" in p2.stdout

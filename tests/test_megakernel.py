"""Batched K-chain megakernel: bit-identity, slot-table service dispatch.

Bit-identity contract (all planar layouts x dtypes, CPU interpret):

  * slot_k in {0, 1} — the serving iteration granularity — is bit-identical
    to the chained single-step path (``plan.step`` per slot; dead slots pass
    through untouched).  This is the path the megakernel replaces in
    continuous serving.
  * deep per-slot chains at PURE storage dtypes are bit-identical to the
    same number of sequential single steps (identical FMA order per
    multiply).
  * deep MIXED-PRECISION chains are bit-identical to the fused in-kernel
    chain (``plan.fused_step(k)``): both upcast once, chain at the
    accumulate width, and narrow once — sequential single steps round
    through storage between multiplies, which is a different (worse)
    numerical contract, not a megakernel bug.

(For f32, deep megakernel chains match sequential steps rather than the
unrolled fused chain: the dynamic per-slot trip count compiles to a loop, so
XLA's FMA contraction differs from the straight-line unrolled body at the
last ulp.  Every multiply is still the exact single-step computation.)
"""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # smoke's fast tier skips these (-m "not slow")

import jax
import jax.numpy as jnp

from repro.core.su3 import registry
from repro.core.su3.layouts import Layout
from repro.core.su3.plan import (
    EngineConfig,
    MEGAKERNEL_VARIANT,
    build_plan,
    make_raw_batched_step,
    make_raw_step,
)
from repro.serve.su3 import BatcherConfig, ServiceConfig, SU3Service

SLOTS = 4


def _rand_batch(plan, slots, seed=0):
    rng = np.random.default_rng(seed)
    S = plan.padded_sites
    a = rng.standard_normal((slots, S, 4, 3, 3, 2)).astype(np.float32)
    b = rng.standard_normal((slots, 4, 3, 3, 2)).astype(np.float32)
    a = jnp.asarray(a[..., 0] + 1j * a[..., 1], jnp.complex64)
    b = jnp.asarray(b[..., 0] + 1j * b[..., 1], jnp.complex64)
    return jax.vmap(plan.codec.pack)(a), jax.vmap(plan.codec.pack_b)(b)


def _plan(layout, dtype="float32", accum=""):
    cfg = EngineConfig(L=2, dtype=dtype, layout=layout, tile=16,
                       accum_dtype=accum)
    return build_plan(cfg)


ALL_PLANS = [
    (Layout.SOA, "float32", ""),
    (Layout.AOSOA, "float32", ""),
    (Layout.SOA, "bfloat16", ""),
    (Layout.AOSOA, "bfloat16", ""),
    (Layout.SOA, "bfloat16", "float32"),
    (Layout.AOSOA, "bfloat16", "float32"),
]


@pytest.mark.parametrize("layout,dtype,accum", ALL_PLANS)
def test_iteration_granularity_bit_identical_to_single_step(layout, dtype, accum):
    """slot_k in {0,1} — what continuous serving dispatches — must equal the
    chained single-step path bit for bit, dead slots passing through."""
    plan = _plan(layout, dtype, accum)
    a_phys, b_p = _rand_batch(plan, SLOTS)
    ks = jnp.array([0, 1, 1, 0], jnp.int32)
    c = plan.fused_batched_step(SLOTS, max_k=4)(a_phys, b_p, ks)
    ref = jnp.stack([
        plan.step(a_phys[s], b_p[s]) if int(ks[s]) else a_phys[s]
        for s in range(SLOTS)
    ])
    assert c.dtype == ref.dtype
    assert bool(jnp.all(c == ref))


@pytest.mark.parametrize("layout,dtype", [
    (Layout.SOA, "float32"), (Layout.AOSOA, "float32"),
    (Layout.SOA, "bfloat16"), (Layout.AOSOA, "bfloat16"),
])
def test_deep_chains_pure_dtype_bit_identical_to_sequential_steps(layout, dtype):
    plan = _plan(layout, dtype)
    a_phys, b_p = _rand_batch(plan, SLOTS)
    ks = jnp.array([1, 2, 3, 4], jnp.int32)
    c = plan.fused_batched_step(SLOTS, max_k=4)(a_phys, b_p, ks)
    ref = []
    for s in range(SLOTS):
        x = a_phys[s]
        for _ in range(int(ks[s])):
            x = plan.step(x, b_p[s])
        ref.append(x)
    assert bool(jnp.all(c == jnp.stack(ref)))


@pytest.mark.parametrize("layout", [Layout.SOA, Layout.AOSOA])
def test_deep_chains_mixed_precision_bit_identical_to_fused_step(layout):
    plan = _plan(layout, "bfloat16", "float32")
    a_phys, b_p = _rand_batch(plan, SLOTS)
    ks = jnp.array([1, 2, 3, 4], jnp.int32)
    c = plan.fused_batched_step(SLOTS, max_k=4)(a_phys, b_p, ks)
    ref = jnp.stack([
        plan.fused_step(int(ks[s]))(a_phys[s], b_p[s]) for s in range(SLOTS)
    ])
    assert bool(jnp.all(c == ref))


def test_slot_k_clamped_to_static_max():
    plan = _plan(Layout.SOA)
    a_phys, b_p = _rand_batch(plan, 2)
    c = plan.fused_batched_step(2, max_k=2)(
        a_phys, b_p, jnp.array([5, 2], jnp.int32))
    ref = plan.fused_batched_step(2, max_k=2)(
        a_phys, b_p, jnp.array([2, 2], jnp.int32))
    assert bool(jnp.all(c == ref))


def test_batched_kernel_is_registered_and_gated():
    entry = registry.get_kernel(MEGAKERNEL_VARIANT)
    assert entry.form == registry.BATCHED
    assert entry.supports_fused and entry.supports_accum
    assert MEGAKERNEL_VARIANT in registry.kernel_names(form=registry.BATCHED)
    # a batched kernel cannot be a plan's single-lattice step...
    codec = _plan(Layout.SOA).codec
    with pytest.raises(ValueError, match="fused_batched_step"):
        make_raw_step(codec, entry, tile=16)
    # ...and the batched step builder rejects non-batched kernels
    with pytest.raises(ValueError, match="batched"):
        make_raw_batched_step(
            codec, registry.get_kernel("pallas"), tile=16, max_k=2)


def test_fused_batched_step_rejects_bad_args():
    plan = _plan(Layout.SOA)
    with pytest.raises(ValueError, match="slots"):
        plan.fused_batched_step(0)
    with pytest.raises(ValueError, match="max_k"):
        plan.fused_batched_step(2, max_k=0)


# -- service slot-table dispatch ----------------------------------------------


def _mega_service(slots=4, horizon=1, hosts=1, max_queue_depth=64):
    return SU3Service(ServiceConfig(
        autotune=False, tile=16, continuous=True, megakernel=True,
        chain_slots=slots, chain_horizon=horizon, hosts=hosts,
        batcher=BatcherConfig(max_batch=slots, warm_batch_sizes=(slots,),
                              max_queue_depth=max_queue_depth),
    ))


def _rand_req(rng, n_sites):
    a = rng.standard_normal((n_sites, 4, 3, 3, 2)).astype(np.float32)
    b = rng.standard_normal((4, 3, 3, 2)).astype(np.float32)
    return (jnp.asarray(a[..., 0] + 1j * a[..., 1], jnp.complex64),
            jnp.asarray(b[..., 0] + 1j * b[..., 1], jnp.complex64))


def _chain_ref(a, b, k):
    x = a
    for _ in range(k):
        x = jnp.einsum("sjkl,jlm->sjkm", x, b)
    return x


def test_megakernel_requires_continuous():
    with pytest.raises(ValueError, match="continuous"):
        ServiceConfig(megakernel=True)
    with pytest.raises(ValueError, match="chain_horizon"):
        ServiceConfig(continuous=True, megakernel=True, chain_horizon=0)


def test_one_dispatch_per_host_per_iteration_mixed_L():
    """The acceptance bar: mixed lattice sizes and chain depths in flight,
    yet every iteration costs exactly ONE host dispatch (the per-(L, chain)
    dispatch tax collapses into the slot table)."""
    svc = _mega_service(slots=4)
    rng = np.random.default_rng(0)
    reqs = [(2, 1), (2, 3), (3, 2), (2, 2), (3, 1)]
    ids, expect = [], []
    for L, k in reqs:
        a, b = _rand_req(rng, L**4)
        ids.append(svc.submit(a, b, k=k))
        expect.append(_chain_ref(a, b, k))
    svc.run_until_drained()
    snap = svc.metrics.snapshot()
    assert snap["completed"] == len(reqs)
    assert snap["dispatches_per_iteration"] == 1.0
    assert snap["host_dispatches"] == {"0": snap["dispatches"]}
    assert snap["midchain_admits"] >= 1  # the 5th request slot-swapped in
    for rid, exp in zip(ids, expect):
        got = svc.pop_result(rid)
        assert float(jnp.max(jnp.abs(got - exp))) < 1e-4


def test_slot_table_grows_for_larger_L_preserving_inflight_state():
    """A bigger lattice arriving mid-flight grows the table capacity; live
    slots re-seat at their mid-chain state and finish correctly."""
    svc = _mega_service(slots=3)
    rng = np.random.default_rng(1)
    a2, b2 = _rand_req(rng, 2**4)
    rid2 = svc.submit(a2, b2, k=3)
    assert svc.step() == 0  # L=2 chain in flight, 2 multiplies to go
    cap_before = svc._tables[0][1].cap_L
    a3, b3 = _rand_req(rng, 3**4)
    rid3 = svc.submit(a3, b3, k=1)
    svc.run_until_drained()
    assert svc._tables[0][1].cap_L == 3 and cap_before == 2
    assert float(jnp.max(jnp.abs(svc.pop_result(rid2) - _chain_ref(a2, b2, 3)))) < 1e-4
    assert float(jnp.max(jnp.abs(svc.pop_result(rid3) - _chain_ref(a3, b3, 1)))) < 1e-4


def test_chain_horizon_amortizes_dispatches():
    """horizon=4 finishes a k=4 request in ONE dispatch where horizon=1
    takes four — the in-kernel chain depth doing the amortizing."""
    rng = np.random.default_rng(2)
    a, b = _rand_req(rng, 2**4)

    svc1 = _mega_service(slots=2, horizon=1)
    rid = svc1.submit(a, b, k=4)
    svc1.run_until_drained()
    one = svc1.pop_result(rid)
    assert svc1.metrics.dispatches == 4

    svc4 = _mega_service(slots=2, horizon=4)
    rid = svc4.submit(a, b, k=4)
    svc4.run_until_drained()
    four = svc4.pop_result(rid)
    assert svc4.metrics.dispatches == 1
    # f32 chains are the same computation either way (see module docstring)
    assert bool(jnp.all(one == four))


def test_megakernel_multihost_routes_and_dispatches_per_host():
    svc = _mega_service(slots=2, hosts=2)
    rng = np.random.default_rng(3)
    ids = {}
    for L in (2, 3):  # router pins each L to its own host
        a, b = _rand_req(rng, L**4)
        ids[L] = (svc.submit(a, b, k=2), _chain_ref(a, b, 2))
    svc.run_until_drained()
    snap = svc.metrics.snapshot()
    assert set(snap["host_dispatches"]) == {"0", "1"}
    for L, (rid, exp) in ids.items():
        assert float(jnp.max(jnp.abs(svc.pop_result(rid) - exp))) < 1e-4


def test_megakernel_warm_compiles_the_table_shape():
    svc = _mega_service(slots=2)
    svc.warm((2,))
    assert ("mega", 2, 2, 1) in svc._seen_shapes
    rng = np.random.default_rng(4)
    a, b = _rand_req(rng, 2**4)
    svc.submit(a, b, k=1)
    svc.run_until_drained()
    assert svc.metrics.compiles == 0, "warmed table shape must not recompile"

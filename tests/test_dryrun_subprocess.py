"""Dry-run integration: lower+compile real cells in a subprocess with a
reduced placeholder device count (device count locks at first jax init, so
these must not run in the main test process)."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # smoke's fast tier skips these (-m "not slow")

ROOT = pathlib.Path(__file__).resolve().parents[1]

CASES = [
    ("whisper-tiny", "train_4k", "single"),
    ("xlstm-125m", "decode_32k", "single"),
    ("granite-moe-1b-a400m", "prefill_32k", "multi"),
    ("zamba2-1.2b", "long_500k", "single"),
]


@pytest.mark.parametrize("arch,shape,mesh", CASES)
def test_dryrun_cell_subprocess(arch, shape, mesh):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["REPRO_XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--mesh", mesh],
        capture_output=True, text=True, env=env, timeout=480, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[ok]" in out.stdout
    result = json.loads(
        (ROOT / "experiments" / "dryrun" / f"{arch}__{shape}__{mesh}.json").read_text()
    )
    assert result["status"] == "ok"
    r = result["roofline"]
    assert r["flops_per_device"] > 0
    assert r["bytes_per_device"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")


def test_dryrun_skips_inapplicable():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env["REPRO_XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "yi-6b", "--shape", "long_500k", "--mesh", "single"],
        capture_output=True, text=True, env=env, timeout=120, cwd=ROOT,
    )
    assert out.returncode == 0
    assert "[skip]" in out.stdout

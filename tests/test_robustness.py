"""Request-lifecycle robustness: deadlines, retries, shedding, quarantine,
fault storms (ISSUE 9).

Pure-logic tests (RetryPolicy, HostHealth, ServiceMetrics counters) run in
microseconds; the service-level tests compile one or two tiny L=2 programs
each.  Fault-injection tests carry the ``chaos`` marker —
``scripts/smoke.sh`` runs :func:`test_storm_zero_lost_and_bitwise_clean`
as its chaos spot-check before the tiers.
"""
import asyncio
import random
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.chaos import FaultPlan, FaultSpec, storm
from repro.core.su3.plan import CGDivergedError
from repro.serve.su3 import (
    PRIORITY,
    BatcherConfig,
    DeadlineExceededError,
    HostHealth,
    LoadShedError,
    RequestFailure,
    RetriesExhaustedError,
    RetryPolicy,
    ServeRequest,
    ServiceConfig,
    ServiceMetrics,
    SU3Service,
)
from repro.serve.su3.batcher import DynamicBatcher

S2 = 16  # L=2 sites


def _rand_ab(seed, n_sites=S2):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n_sites, 4, 3, 3, 2))
    a = jax.lax.complex(g[..., 0], g[..., 1])
    h = jax.random.normal(jax.random.PRNGKey(seed + 10_000), (4, 3, 3, 2))
    return a, jax.lax.complex(h[..., 0], h[..., 1])


def _svc(**kw):
    cfg = dict(autotune=False, tile=16)
    cfg.update(kw)
    return SU3Service(ServiceConfig(**cfg))


def _req(i, L=2, k=1, priority=0, deadline_s=0.0, arrival=None):
    return ServeRequest(req_id=i, a=None, b=None, L=L, k=k,
                        arrival_s=i + 1.0 if arrival is None else arrival,
                        priority=priority, deadline_s=deadline_s)


# -- RetryPolicy (pure) --------------------------------------------------------


def test_retry_policy_backoff_doubles_to_cap_with_bounded_jitter():
    pol = RetryPolicy(base_s=0.01, cap_s=0.05, jitter=0.25)
    rng = random.Random(0)
    raws = [0.01, 0.02, 0.04, 0.05, 0.05]  # doubles, then pinned at cap
    for attempt, raw in enumerate(raws, start=1):
        for _ in range(20):
            d = pol.backoff_s(attempt, rng)
            assert raw <= d <= raw * 1.25


def test_retry_policy_zero_jitter_is_deterministic():
    pol = RetryPolicy(base_s=0.002, cap_s=0.25, jitter=0.0)
    rng = random.Random(3)
    assert pol.backoff_s(1, rng) == 0.002
    assert pol.backoff_s(4, rng) == 0.016
    assert pol.backoff_s(40, rng) == 0.25


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_retries"):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="base_s"):
        RetryPolicy(base_s=0.5, cap_s=0.1)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=2.0)
    with pytest.raises(ValueError, match="budget"):
        RetryPolicy(budget=-5)


# -- HostHealth (pure) ---------------------------------------------------------


def test_host_health_quarantines_after_consecutive_failures():
    h = HostHealth(3, quarantine_after=2)
    assert h.record_failure(0, "boom") is False
    assert h.record_failure(0, "boom") is True  # the crossing returns True
    assert h.record_failure(0, "boom") is False  # already latched: once only
    assert h.quarantined() == {0} and h.is_quarantined(0)
    assert h.healthy_hosts() == [1, 2]
    snap = h.snapshot()
    assert snap["quarantined"] == [0] and snap["last_cause"][0] == "boom"


def test_host_health_success_resets_the_consecutive_count():
    h = HostHealth(2, quarantine_after=3)
    h.record_failure(1, "a")
    h.record_failure(1, "b")
    h.record_success(1)
    assert h.consecutive[1] == 0
    assert h.record_failure(1, "c") is False  # count restarted, no latch
    assert h.failures[1] == 3 and h.successes[1] == 1


def test_host_health_never_quarantines_the_last_healthy_host():
    solo = HostHealth(1, quarantine_after=1)
    for _ in range(5):
        assert solo.record_failure(0, "x") is False  # keeps retrying instead
    assert solo.quarantined() == set()

    pair = HostHealth(2, quarantine_after=1)
    assert pair.record_failure(0, "x") is True
    assert pair.record_failure(1, "x") is False  # 1 is the last one standing
    assert pair.healthy_hosts() == [1]


def test_host_health_reinstate_clears_the_latch():
    h = HostHealth(2, quarantine_after=1)
    h.record_failure(0, "x")
    h.reinstate(0)
    assert h.healthy_hosts() == [0, 1] and h.consecutive[0] == 0
    with pytest.raises(ValueError):
        HostHealth(0)
    with pytest.raises(ValueError):
        HostHealth(2, quarantine_after=0)


# -- ServiceMetrics robustness counters (pure) ---------------------------------


def test_metrics_robustness_counters_and_per_kind_splits():
    m = ServiceMetrics()
    m.record_reject("solve")
    m.record_reject("solve")
    m.record_reject()  # defaults to multiply: pre-existing call sites
    m.record_shed("multiply")
    m.record_timeout("solve")
    m.record_retry()
    m.record_retry(2)
    m.record_retries_exhausted()
    m.record_fault()
    m.record_degraded()
    m.record_quarantine(reseated=3)
    snap = m.snapshot()
    assert snap["rejected"] == 3  # the pre-existing total key is unchanged
    assert snap["rejected_by_kind"] == {"solve": 2, "multiply": 1}
    assert snap["shed"] == 1 and snap["shed_by_kind"] == {"multiply": 1}
    assert snap["timeouts"] == 1 and snap["timeouts_by_kind"] == {"solve": 1}
    assert snap["retries"] == 3
    assert snap["retries_exhausted"] == 1
    assert snap["faults_injected"] == 1
    assert snap["degraded_dispatches"] == 1
    assert snap["quarantines"] == 1 and snap["reseated"] == 3
    # the legacy surface bench rows key on is still there
    for key in ("completed", "dispatches", "latency_p50_ms",
                "mean_batch_occupancy", "queue_depth_max"):
        assert key in snap


# -- batcher eviction/shedding (pure queue ops) --------------------------------


def test_batcher_evict_expired_removes_only_past_deadline():
    b = DynamicBatcher(BatcherConfig(max_batch=8, warm_batch_sizes=(1, 8)))
    b.submit(_req(0, deadline_s=5.0))
    b.submit(_req(1, deadline_s=100.0))
    b.submit(_req(2))  # no deadline: never expires
    evicted = b.evict_expired(now=10.0)
    assert [r.req_id for r in evicted] == [0]
    assert len(b) == 2


def test_batcher_sheds_youngest_lowest_priority_first():
    b = DynamicBatcher(BatcherConfig(max_batch=8, warm_batch_sizes=(1, 8)))
    b.submit(_req(0, priority=PRIORITY["multiply"], arrival=1.0))
    b.submit(_req(1, priority=PRIORITY["multiply"], arrival=2.0))
    b.submit(_req(2, priority=PRIORITY["solve"], arrival=3.0))
    victim = b.shed_lowest(max_priority=PRIORITY["solve"])
    assert victim.req_id == 1  # youngest of the lowest priority class
    # nothing queued sits below multiply priority, so a multiply arrival
    # finds no victim, and a queue of solves never sheds for another solve
    assert b.shed_lowest(max_priority=PRIORITY["multiply"]) is None
    b2 = DynamicBatcher(BatcherConfig(max_batch=8, warm_batch_sizes=(1, 8)))
    b2.submit(_req(0, priority=PRIORITY["solve"]))
    assert b2.shed_lowest(max_priority=PRIORITY["solve"]) is None


# -- deadlines (service) -------------------------------------------------------


def test_deadline_evicts_queued_request_with_structured_timeout():
    svc = _svc()
    a, b = _rand_ab(0)
    rid = svc.submit(a, b, k=1, deadline_s=0.01)
    time.sleep(0.05)
    svc.step()  # the sweep runs before dispatch
    out = svc.pop_result(rid)
    assert isinstance(out, DeadlineExceededError)
    assert out.req_id == rid and out.kind == "multiply"
    assert out.waited_s >= 0.01 and out.partial is None
    assert svc.metrics.snapshot()["timeouts_by_kind"] == {"multiply": 1}
    assert not svc.pending()


def test_deadline_evicts_active_solve_and_carries_partial():
    from benchmarks.cg_solve import _problem

    svc = _svc(solve_iters_per_step=1)
    u, b = _problem(2)
    rid = svc.submit_solve(u, b, tol=1e-12, max_iters=500, deadline_s=30.0)
    svc.step()  # seat + first iterations
    assert svc._solves  # seated
    active = next(iter(svc._solves.values()))
    active["req"].deadline_s = time.perf_counter() - 1.0  # force expiry
    svc.step()
    out = svc.pop_result(rid)
    assert isinstance(out, DeadlineExceededError) and out.kind == "solve"
    assert out.partial is not None  # the best iterate rides out
    assert out.partial.shape[0] == 2**4
    assert not svc._solves and not svc.pending()


@pytest.mark.chaos
def test_deadline_evicts_only_live_slot_in_megakernel_table():
    # satellite edge case: the sweep empties a slot table down to zero live
    # slots mid-chain; the table must idle cleanly and the next admit reuses
    # the freed seat
    svc = _svc(continuous=True, megakernel=True, chain_slots=2,
               chain_horizon=1,
               batcher=BatcherConfig(max_batch=2, warm_batch_sizes=(2,),
                                     max_queue_depth=8))
    a, b = _rand_ab(1)
    rid = svc.submit(a, b, k=6, deadline_s=60.0)
    for _ in range(2):
        svc.step()
    (table, _arrays), = svc._tables.values()
    occupants = table.occupants()
    assert len(occupants) == 1  # the only live slot
    occupants[0][1].deadline_s = time.perf_counter() - 1.0
    svc.step()  # sweep evicts; the empty table must not dispatch or crash
    out = svc.pop_result(rid)
    assert isinstance(out, DeadlineExceededError)
    assert table.live == 0
    # the freed seat serves the next request end-to-end
    a2, b2 = _rand_ab(2)
    rid2 = svc.submit(a2, b2, k=2)
    svc.run_until_drained()
    # the megakernel's reduction order differs from the plain runner's, so
    # the cross-path check is allclose, not bitwise
    ref = _svc().runner_for(2).multiply(a2[None], b2[None], k=2)[0]
    np.testing.assert_allclose(
        np.abs(np.asarray(svc.pop_result(rid2) - ref)), 0.0, atol=1e-4)


@pytest.mark.chaos
def test_midchain_eviction_frees_seat_for_pending_same_L_admit():
    # satellite edge case: a same-L request waits in the queue while the
    # chain is full; the deadline eviction must free the seat through the
    # same re-seating machinery mid-chain admission uses
    svc = _svc(continuous=True, chain_slots=1, chain_horizon=1,
               batcher=BatcherConfig(max_batch=1, warm_batch_sizes=(1,),
                                     max_queue_depth=8))
    a1, b1 = _rand_ab(3)
    a2, b2 = _rand_ab(4)
    rid1 = svc.submit(a1, b1, k=8, deadline_s=60.0)
    svc.step()  # seat rid1 into the single chain slot
    rid2 = svc.submit(a2, b2, k=1)  # same-L admit pending behind a full chain
    svc.step()
    (chain, _arrays), = svc._chains.values()
    occ = chain.occupants()
    assert [o[1].req_id for o in occ] == [rid1]
    occ[0][1].deadline_s = time.perf_counter() - 1.0
    svc.run_until_drained()
    assert isinstance(svc.pop_result(rid1), DeadlineExceededError)
    ref = _svc().runner_for(2).multiply(a2[None], b2[None], k=1)[0]
    assert bool(jnp.array_equal(svc.pop_result(rid2), ref))


@pytest.mark.chaos
def test_deadline_eviction_on_quarantined_host_reseats_then_times_out():
    # satellite edge case: work seated on a host that gets quarantined is
    # re-seated onto a healthy pool; an expired deadline must still produce
    # a structured timeout (never a silent drop) after the move
    svc = _svc(hosts=2, continuous=True, chain_slots=1, chain_horizon=1,
               quarantine_after=1,
               batcher=BatcherConfig(max_batch=1, warm_batch_sizes=(1,),
                                     max_queue_depth=8))
    a, b = _rand_ab(5)
    home = svc.router.host_for(2)
    rid = svc.submit(a, b, k=8, deadline_s=60.0)
    svc.step()  # seat on the home host
    assert any(k[0] == home for k in svc._chains)
    svc.health.record_failure(home, "test latch")
    svc._quarantine(home)
    assert svc.health.is_quarantined(home)
    assert svc.metrics.snapshot()["quarantines"] == 1
    # the re-seated request sits on the healthy host — queued or already
    # chained; step until it holds a seat, then force expiry there
    deadline_past = time.perf_counter() - 1.0
    found = False
    for _ in range(20):
        for chain, _arr in svc._chains.values():
            for _slot, r, _rem in chain.occupants():
                if r.req_id == rid:
                    r.deadline_s = deadline_past
                    found = True
        if found:
            break
        svc.step()
    assert found, "request lost during quarantine re-seat"
    svc.run_until_drained()
    out = svc.pop_result(rid)
    assert isinstance(out, DeadlineExceededError)
    assert not svc.pending()


def test_reseat_resolves_already_expired_deadline_exactly_once():
    # the deadline-expiry x re-seat race: a request whose deadline passed
    # BEFORE the quarantine/scale-down re-seat runs must resolve as exactly
    # one DeadlineExceededError at re-seat time — never resubmitted for the
    # next sweep to evict (double resolution), never silently dropped
    svc = _svc(hosts=2)
    now = time.perf_counter()
    req = _req(1, deadline_s=now - 0.01, arrival=now - 0.5)
    reseated = svc._reseat([req], "re-seat rejected")
    assert reseated == 0
    assert svc.queued() == 0  # never re-entered any queue
    out = svc.pop_result(1)
    assert isinstance(out, DeadlineExceededError)
    assert not svc.has_result(1)  # resolved once; nothing left behind
    assert svc.metrics.snapshot()["timeouts_by_kind"] == {"multiply": 1}
    # a live-deadline companion in the same batch re-seats normally
    fresh = _req(2, deadline_s=now + 60.0, arrival=now)
    assert svc._reseat([fresh], "re-seat rejected") == 1
    assert svc.queued() == 1
    assert svc.metrics.snapshot()["timeouts"] == 1


# -- load shedding -------------------------------------------------------------


def test_solve_arrival_sheds_queued_multiply_under_backpressure():
    from benchmarks.cg_solve import _problem

    svc = _svc(batcher=BatcherConfig(max_batch=1, warm_batch_sizes=(1,),
                                     max_queue_depth=1))
    a, b = _rand_ab(6)
    rid_m = svc.submit(a, b, k=1)  # fills the depth-1 queue
    u, rhs = _problem(2)
    rid_s = svc.submit_solve(u, rhs, tol=1e-6, max_iters=64)
    assert rid_s is not None  # admitted by shedding the multiply
    out = svc.pop_result(rid_m)
    assert isinstance(out, LoadShedError)
    assert out.shed_for_kind == "solve" and out.priority == PRIORITY["multiply"]
    svc.run_until_drained()
    x = svc.pop_result(rid_s)
    assert not isinstance(x, Exception) and bool(jnp.all(jnp.isfinite(jnp.real(x))))
    snap = svc.metrics.snapshot()
    assert snap["shed"] == 1 and snap["shed_by_kind"] == {"multiply": 1}


def test_multiply_arrival_cannot_shed_an_equal_priority_multiply():
    svc = _svc(batcher=BatcherConfig(max_batch=1, warm_batch_sizes=(1,),
                                     max_queue_depth=1))
    a, b = _rand_ab(7)
    rid1 = svc.submit(a, b, k=1)
    rid2 = svc.submit(*_rand_ab(8), k=1)  # equal priority: rejected, not shed
    assert rid1 is not None and rid2 is None
    assert svc.metrics.snapshot()["rejected_by_kind"] == {"multiply": 1}
    svc.run_until_drained()
    assert not isinstance(svc.pop_result(rid1), Exception)


# -- arun backpressure backoff (satellite: no busy-spin) -----------------------


def test_arun_backs_off_exponentially_instead_of_busy_spinning():
    svc = _svc(retry=RetryPolicy(base_s=0.02, cap_s=0.2, jitter=0.2))
    a, b = _rand_ab(9)
    times = []
    real_submit, real_step = svc.submit, svc.step
    svc.step = lambda: 0  # the service is stalled while it rejects

    def stub(aa, bb, k=None, deadline_s=None, **kw):
        times.append(time.perf_counter())
        if len(times) <= 4:
            return None  # sustained backpressure
        svc.step = real_step  # service unstalls; let the request complete
        return real_submit(aa, bb, k, deadline_s=deadline_s, **kw)

    svc.submit = stub
    out = asyncio.run(svc.arun(a, b, k=1))
    assert bool(jnp.all(jnp.isfinite(jnp.real(out))))
    assert len(times) == 5  # 4 rejections + 1 success: no spin storm
    gaps = [t1 - t0 for t0, t1 in zip(times, times[1:])]
    # gap 0 is the same-tick fast path; the rest follow the jittered
    # exponential schedule (>= 90% of the raw delay, well past spin speed)
    assert gaps[1] >= 0.02 * 0.9
    assert gaps[2] >= 0.04 * 0.9
    assert gaps[3] >= 0.08 * 0.9


def test_arun_raises_structured_failures():
    svc = _svc()
    a, b = _rand_ab(10)
    real_step = svc.step

    def slow_step():  # the deadline lapses before the first dispatch runs
        time.sleep(0.02)
        return real_step()

    svc.step = slow_step

    async def go():
        with pytest.raises(DeadlineExceededError):
            await svc.arun(a, b, k=1, deadline_s=0.01)

    asyncio.run(go())


# -- fault storms (chaos) ------------------------------------------------------


def _storm_svc(plan, **kw):
    cfg = dict(
        autotune=False, tile=16, faults=plan,
        retry=RetryPolicy(max_retries=6, base_s=1e-6, cap_s=1e-5),
        batcher=BatcherConfig(max_batch=4, warm_batch_sizes=(1, 2, 4),
                              max_queue_depth=64),
    )
    cfg.update(kw)
    return SU3Service(ServiceConfig(**cfg))


@pytest.mark.chaos
def test_storm_zero_lost_and_bitwise_clean():
    """The smoke.sh chaos spot-check: a seeded dispatch+kernel+pool storm
    over a multiply stream loses nothing, and every retried success is
    bitwise identical to the fault-free baseline."""
    reqs = [_rand_ab(100 + i) for i in range(6)]

    def run_once(plan):
        svc = _storm_svc(plan)
        ids = [svc.submit(a, b, k=2) for a, b in reqs]
        svc.run_until_drained()
        return {rid: svc.pop_result(rid) for rid in ids}, svc

    clean, _ = run_once(None)
    assert all(not isinstance(v, Exception) for v in clean.values())

    plan = storm(13, dispatch_p=0.5, kernel_p=0.4, pool_p=0.5, max_fires=4)
    chaotic, svc = run_once(plan)
    assert plan.fired > 0, "the storm must actually fire"
    for rid_c, rid_b in zip(chaotic, clean):
        out = chaotic[rid_c]
        assert out is not None, "lost request"
        if isinstance(out, Exception):
            assert isinstance(out, RequestFailure)  # structured, attributable
        else:
            assert bool(jnp.array_equal(out, clean[rid_b]))
    assert svc.metrics.snapshot()["faults_injected"] >= plan.fired - 1
    # same seed, same schedule -> same per-site fault sequence end-to-end
    replay_plan = plan.reset()
    run_once(replay_plan)
    key = lambda e: (e["site"], e["action"], e["site_seq"])  # noqa: E731
    assert sorted(map(key, plan.log())) == sorted(map(key, replay_plan.log()))


@pytest.mark.chaos
def test_unbounded_dispatch_failure_exhausts_retries_structurally():
    plan = FaultPlan(0, {"dispatch": FaultSpec(probability=1.0,
                                               actions=("fail",))})
    svc = _storm_svc(plan, retry=RetryPolicy(max_retries=2, base_s=1e-6,
                                             cap_s=1e-5))
    a, b = _rand_ab(11)
    rid = svc.submit(a, b, k=1)
    svc.run_until_drained()
    out = svc.pop_result(rid)
    assert isinstance(out, RetriesExhaustedError)
    assert out.attempts == 3  # first try + 2 retries
    assert "dispatch" in out.cause
    assert not svc.pending()  # drained, never hung
    assert svc.health.quarantined() == set()  # a lone host is never latched


@pytest.mark.chaos
def test_quarantine_reseats_onto_the_healthy_host_bitwise_clean():
    # host A fails 3 consecutive dispatches -> latched; its work re-homes to
    # host B and completes identical to a clean single-host run
    plan = FaultPlan(1, {"dispatch": FaultSpec(probability=1.0,
                                               actions=("fail",),
                                               max_fires=3)})
    svc = _storm_svc(plan, hosts=2, quarantine_after=3,
                     retry=RetryPolicy(max_retries=10, base_s=1e-6,
                                       cap_s=1e-5))
    a, b = _rand_ab(12)
    home = svc.router.host_for(2)
    rid = svc.submit(a, b, k=2)
    svc.run_until_drained(max_steps=100_000)
    out = svc.pop_result(rid)
    assert not isinstance(out, Exception)
    assert svc.health.quarantined() == {home}
    assert svc.metrics.snapshot()["quarantines"] == 1
    ref_svc = _svc()
    rid_ref = ref_svc.submit(a, b, k=2)
    ref_svc.run_until_drained()
    assert bool(jnp.array_equal(out, ref_svc.pop_result(rid_ref)))
    svc.health.reinstate(home)
    assert svc.health.healthy_hosts() == [0, 1]


@pytest.mark.chaos
def test_megakernel_dispatch_failure_degrades_to_chained_path():
    # a failed megakernel batch re-dispatches down the per-slot chained
    # path: numerically equivalent (different reduction order), not lost
    plan = FaultPlan(2, {"dispatch": FaultSpec(probability=1.0,
                                               actions=("fail",),
                                               max_fires=1)})
    svc = _storm_svc(plan, continuous=True, megakernel=True, chain_slots=2,
                     chain_horizon=1,
                     batcher=BatcherConfig(max_batch=2, warm_batch_sizes=(2,),
                                           max_queue_depth=8))
    reqs = [_rand_ab(200 + i) for i in range(2)]
    ids = [svc.submit(a, b, k=2) for a, b in reqs]
    svc.run_until_drained()
    snap = svc.metrics.snapshot()
    assert snap["degraded_dispatches"] >= 1
    ref = _svc()
    for rid, (a, b) in zip(ids, reqs):
        out = svc.pop_result(rid)
        assert not isinstance(out, Exception)
        expect = ref.runner_for(2).multiply(a[None], b[None], k=2)[0]
        np.testing.assert_allclose(
            np.abs(np.asarray(out - expect)), 0.0, atol=1e-4)


@pytest.mark.chaos
def test_solve_kernel_poison_retries_to_the_clean_answer():
    # one poisoned CG residual -> the numerics guard unseats the solve, the
    # retry re-runs it from scratch, and the answer matches the clean run
    from benchmarks.cg_solve import _problem

    u, b = _problem(2)
    clean_svc = _svc(solve_iters_per_step=4)
    rid0 = clean_svc.submit_solve(u, b, tol=1e-6, max_iters=64)
    clean_svc.run_until_drained()
    x_clean = clean_svc.pop_result(rid0)

    plan = FaultPlan(4, {"kernel": FaultSpec(probability=1.0,
                                             actions=("nan",), max_fires=1)})
    svc = _storm_svc(plan, solve_iters_per_step=4)
    rid = svc.submit_solve(u, b, tol=1e-6, max_iters=64)
    svc.run_until_drained()
    out = svc.pop_result(rid)
    assert plan.fired == 1
    assert svc.metrics.snapshot()["retries"] >= 1
    assert not isinstance(out, Exception)
    assert bool(jnp.array_equal(out, x_clean))


@pytest.mark.chaos
def test_solve_divergence_is_structured_not_a_hang():
    # an unbounded kernel-poison storm makes every retry diverge: the solve
    # must resolve as CGDivergedError with the fault provenance intact
    from benchmarks.cg_solve import _problem

    u, b = _problem(2)
    plan = FaultPlan(5, {"kernel": FaultSpec(probability=1.0,
                                             actions=("nan",))})
    svc = _storm_svc(plan, solve_iters_per_step=2,
                     retry=RetryPolicy(max_retries=1, base_s=1e-6,
                                       cap_s=1e-5))
    rid = svc.submit_solve(u, b, tol=1e-6, max_iters=64)
    svc.run_until_drained()
    out = svc.pop_result(rid)
    assert isinstance(out, CGDivergedError)
    assert "non-finite" in str(out)
    assert not svc.pending()

"""Multi-host plan layer: MeshSpec topology, lattice site/halo sharding
rules, locality routing, and (in a forced-device subprocess) 2-host plan
execution equality with per-host first-touch init."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.core.su3 import layouts
from repro.core.su3.layouts import Layout
from repro.distributed import sharding
from repro.launch.mesh import DEVICE_AXIS, HOST_AXIS, MeshSpec
from repro.serve.su3 import LocalityRouter


def _fake_mesh(hosts, dph):
    """A (hosts, devices) mesh over one repeated device — construction and
    spec resolution only, never executed (the simulated 2-host mesh)."""
    dev = jax.devices()[0]
    return MeshSpec(hosts=hosts, devices_per_host=dph).resolve([dev] * (hosts * dph))


# -- MeshSpec topology --------------------------------------------------------


def test_meshspec_resolves_host_device_mesh():
    mesh = _fake_mesh(2, 2)
    assert mesh.axis_names == (HOST_AXIS, DEVICE_AXIS)
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {"hosts": 2, "devices": 2}


def test_meshspec_single_host_is_legacy_site_mesh():
    mesh = MeshSpec.single_host().resolve([jax.devices()[0]])
    assert mesh.axis_names == ("sites",)


def test_meshspec_validation_and_oversubscription():
    with pytest.raises(ValueError, match="hosts"):
        MeshSpec(hosts=0)
    with pytest.raises(ValueError, match="needs"):
        MeshSpec(hosts=4, devices_per_host=4).resolve([jax.devices()[0]])
    # short local pool: every simulated host shares the head of the list
    spec = MeshSpec(hosts=2, devices_per_host=1)
    assert spec.host_devices(0) == spec.host_devices(1) == jax.devices()[:1]
    with pytest.raises(ValueError, match="out of range"):
        spec.host_devices(2)
    sub = spec.host_submesh(1)
    assert sub.axis_names == ("sites",) and sub.devices.size == 1


def test_meshspec_host_major_device_assignment():
    devs = [jax.devices()[0]] * 4
    spec = MeshSpec(hosts=2, devices_per_host=2)
    assert spec.host_devices(0, devs) == devs[0:2]
    assert spec.host_devices(1, devs) == devs[2:4]
    assert spec.n_devices(devs) == 4 and spec.is_multi_host


# -- lattice site/halo sharding rules ----------------------------------------


def test_lattice_site_axes_and_spec():
    mh = _fake_mesh(2, 2)
    assert sharding.lattice_site_axes(mh) == ("hosts", "devices")
    assert sharding.lattice_is_multi_host(mh)
    single = MeshSpec.single_host().resolve([jax.devices()[0]])
    assert sharding.lattice_site_axes(single) == ("sites",)
    assert not sharding.lattice_is_multi_host(single)

    codec = layouts.make_codec(Layout.SOA, tile=16)
    assert sharding.lattice_site_spec(codec, mh) == P(None, None, ("hosts", "devices"))
    assert sharding.lattice_site_spec(codec, single) == P(None, None, "sites")
    aos = layouts.make_codec(Layout.AOS, tile=16)
    assert sharding.lattice_site_spec(aos, mh) == P(("hosts", "devices"), None)
    aosoa = layouts.make_codec(Layout.AOSOA, tile=16)
    assert sharding.lattice_site_spec(aosoa, mh) == P(("hosts", "devices"), None, None, None)


def test_host_site_ranges_contiguous_slabs():
    mesh = _fake_mesh(2, 2)
    assert sharding.host_site_ranges(256, mesh) == [(0, 128), (128, 256)]
    single = MeshSpec.single_host().resolve([jax.devices()[0]])
    assert sharding.host_site_ranges(256, single) == [(0, 256)]
    with pytest.raises(ValueError, match="divide"):
        sharding.host_site_ranges(255, mesh)


def test_halo_spec_boundary_geometry():
    mesh = _fake_mesh(2, 1)
    h = sharding.halo_spec(4, mesh)
    assert h.sites_per_shard == 128
    assert h.face_sites == 64 and h.boundary_sites == 128
    assert h.halo_bytes_per_exchange == 128 * 72 * 4
    assert h.interior_fraction == 0.0  # L=4 over 2 hosts: slab is all surface
    h8 = sharding.HaloSpec(L=8, n_shards=2, word_bytes=2)  # bf16 storage
    assert h8.sites_per_shard == 2048 and h8.boundary_sites == 1024
    assert h8.interior_fraction == 0.5
    assert h8.halo_bytes_per_exchange == 1024 * 72 * 2
    single = MeshSpec.single_host().resolve([jax.devices()[0]])
    assert sharding.halo_spec(4, single).boundary_sites == 0  # unsharded


# -- locality routing ---------------------------------------------------------


def test_locality_router_sticky_and_least_loaded():
    r = LocalityRouter(2)
    h2 = r.host_for(2)
    r.record_load(h2, 1000.0)
    h4 = r.host_for(4)
    assert h4 != h2  # new L lands on the less-loaded host
    r.record_load(h4, 10_000.0)
    assert r.host_for(2) == h2 and r.host_for(4) == h4  # sticky forever
    assert r.peek(8) is None and r.peek(2) == h2
    assert r.assignments() == {2: h2, 4: h4}
    with pytest.raises(ValueError, match="n_hosts"):
        LocalityRouter(0)


# -- execution on a real (forced-device) 2-host mesh --------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
from repro.core.su3 import plan
from repro.core.su3.engine import EngineConfig
from repro.core.su3.layouts import Layout
from repro.launch.mesh import MeshSpec

out = {}
for layout, variant in (("soa", "pallas"), ("aos", "versionX")):
    cfg = EngineConfig(L=2, layout=Layout(layout), variant=variant, tile=16,
                       iterations=1, warmups=0)
    p1 = plan.build_plan(cfg)  # 1-D site mesh over all 4 devices
    p2 = plan.build_plan(cfg, MeshSpec(hosts=2, devices_per_host=2))
    assert p2.is_multi_host and p2.n_hosts == 2
    assert p2.site_axes == ("hosts", "devices")
    a1, b1, _, _ = p1.init_data()
    a2, b2, _, _ = p2.init_data()  # per-host first-touch path
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(a1)), np.asarray(jax.device_get(a2)))
    c1, c2 = p1.step(a1, b1), p2.step(a2, b2)
    assert p2.verify(c2)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(c1)), np.asarray(jax.device_get(c2)))
    f = p2.fused_step(3)(a2, b2)
    assert f.sharding == p2.sharding  # chain output stays shard-local
    out[layout] = p2.describe()
print(json.dumps(out))
"""


def test_two_host_plan_matches_single_host_subprocess(forced_subprocess_json):
    """Real execution needs >1 device: forced host-platform devices lock at
    first jax init, so this runs in a subprocess (no hardware needed) via
    the shared conftest runner."""
    described = forced_subprocess_json(_SUBPROC)
    assert described["soa"] == "soa/pallas/t16/sharded@4devx2h/float32"
    assert described["aos"] == "aos/versionX/t16/sharded@4devx2h/float32"


def test_fig7_digest_is_padding_independent():
    """The divergence gate compares digests across device counts whose plans
    pad the lattice differently; the RNG draw must cover exactly the live
    sites or identical results digest differently (false DIVERGENCE)."""
    from repro.core.su3 import plan
    from repro.core.su3.engine import EngineConfig
    from repro.launch.dryrun import _su3_result_digest

    cfg16 = EngineConfig(L=2, tile=16, iterations=1, warmups=0)
    cfg128 = EngineConfig(L=2, tile=128, iterations=1, warmups=0)
    p16, p128 = plan.build_plan(cfg16), plan.build_plan(cfg128)
    assert p16.padded_sites != p128.padded_sites  # genuinely different padding
    assert _su3_result_digest(p16, seed=0) == _su3_result_digest(p128, seed=0)


# -- first-touch shard builder (host-side, no multi-device needed) ------------


def test_uniform_phys_shard_matches_codec_pack():
    from repro.core.su3.plan import _uniform_phys_shard, init_canonical

    for layout in Layout:
        codec = layouts.make_codec(layout, tile=16)
        want = np.asarray(codec.pack(init_canonical(32)[0]))
        got = _uniform_phys_shard(codec, 32, 0)
        np.testing.assert_array_equal(got, want, err_msg=layout.value)
    # offset shards only shift AOS metadata words, never the gauge field
    aos = layouts.make_codec(Layout.AOS, tile=16)
    shard = _uniform_phys_shard(aos, 16, 100)
    assert shard[0, layouts.GAUGE_WORDS] == 100.0  # global site id
    np.testing.assert_array_equal(
        shard[:, :layouts.GAUGE_WORDS],
        _uniform_phys_shard(aos, 16, 0)[:, :layouts.GAUGE_WORDS],
    )

"""Chunked-flash attention and MLA vs full-materialization oracles; decode
parity with the training path (the strongest serving-correctness check)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import ref as kref
from repro.models import attention, common, mla


@pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_ref(hq, hkv, causal):
    k = jax.random.PRNGKey(hq * 10 + hkv)
    q = jax.random.normal(k, (2, 32, hq, 16))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 32, hkv, 16))
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 32, hkv, 16))
    out = attention.flash_attention(q, kk, v, causal=causal, q_chunk=8, kv_chunk=8)
    expected = kref.flash_attention_ref(q, kk, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=2e-5, atol=2e-5)


def test_flash_ragged_lengths():
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (2, 30, 4, 8))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (2, 30, 4, 8))
    v = jax.random.normal(jax.random.fold_in(k, 2), (2, 30, 4, 8))
    out = attention.flash_attention(q, kk, v, causal=True, q_chunk=16, kv_chunk=16)
    expected = kref.flash_attention_ref(q, kk, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=2e-5, atol=2e-5)


def _gqa_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=8,
                n_kv_heads=2, d_ff=128, vocab_size=101, qk_norm=True, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_attention_decode_matches_full():
    cfg = _gqa_cfg()
    params = common.init_params(attention.spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 64))
    pos = jnp.broadcast_to(jnp.arange(24), (2, 24))
    full = attention.attention_ref(params, x, cfg, pos)
    cache = attention.init_cache(cfg, 2, 24, jnp.float32)
    outs = []
    for t in range(24):
        o, cache = attention.apply(
            params, x[:, t : t + 1], cfg, positions=pos[:, t : t + 1],
            cache=cache, cur_len=jnp.int32(t),
        )
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), rtol=1e-4, atol=1e-4
    )


def _mla_cfg():
    return ModelConfig(
        name="m", family="moe", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=97, dtype="float32", use_mla=True, q_lora_rank=48,
        kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    )


def test_mla_flash_vs_ref():
    cfg = _mla_cfg()
    params = common.init_params(mla.spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    out, _ = mla.apply(params, x, cfg, positions=pos, q_chunk=8, kv_chunk=8)
    expected = mla.mla_ref(params, x, cfg, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-4, atol=1e-4)


def test_mla_absorbed_decode_matches_ref():
    """The absorbed-latent decode must agree with decompressed attention."""
    cfg = _mla_cfg()
    params = common.init_params(mla.spec(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    expected = mla.mla_ref(params, x, cfg, pos)
    cache = mla.init_cache(cfg, 2, 16, jnp.float32)
    outs = []
    for t in range(16):
        o, cache = mla.apply(
            params, x[:, t : t + 1], cfg, positions=pos[:, t : t + 1],
            cache=cache, cur_len=jnp.int32(t),
        )
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(expected), rtol=1e-4, atol=1e-4
    )


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions."""
    d = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 1, d))
    p0 = jnp.arange(4)[None]
    p1 = p0 + 100
    s0 = jnp.einsum(
        "bshd,bthd->bst",
        common.apply_rope(q, p0, 1e4), common.apply_rope(k, p0, 1e4),
    )
    s1 = jnp.einsum(
        "bshd,bthd->bst",
        common.apply_rope(q, p1, 1e4), common.apply_rope(k, p1, 1e4),
    )
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=1e-4, atol=1e-5)

"""Sharding resolver unit tests: divisibility fallbacks, axis-conflict
avoidance, state-sharding rules, and the locality invariant."""
import os
import subprocess
import sys

import hypothesis
import hypothesis.strategies as st
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding
from repro.models.common import ParamSpec

pytestmark = pytest.mark.skipif(
    len(jax.devices()) != 1, reason="resolver tests build their own meshes"
)


def _mesh(shape=(2, 4), axes=("data", "model")):
    # single-device container: build a mesh over 1 device when needed
    import math

    import numpy as np

    n = math.prod(shape)
    if len(jax.devices()) < n:
        dev = np.array(jax.devices()[:1] * n).reshape(shape)
        return jax.sharding.Mesh(dev, axes)
    return jax.make_mesh(shape, axes)


def test_resolver_basic_tp():
    mesh = _mesh()
    rules = sharding.MeshRules(data_axes=("data",), fsdp_axes=("data",), model_axes=("model",))
    spec = sharding.resolve_spec(("embed", "mlp"), (64, 128), mesh, rules)
    assert spec == P("data", "model")


def test_resolver_divisibility_fallback():
    mesh = _mesh()
    rules = sharding.default_rules(mesh)
    # kv_heads=1 cannot shard over model(4) -> replicated
    spec = sharding.resolve_spec(("embed", "kv_heads", None), (64, 1, 128), mesh, rules)
    assert spec in (P("data"), P("data", None), P("data", None, None))
    # odd dim cannot shard over data(2)
    spec = sharding.resolve_spec(("embed",), (63,), mesh, rules)
    assert spec == P()


def test_resolver_no_axis_reuse():
    mesh = _mesh()
    rules = sharding.MeshRules(
        data_axes=("data",), fsdp_axes=("model",), model_axes=("model",)
    )
    # both dims want 'model': only the first gets it
    spec = sharding.resolve_spec(("embed", "mlp"), (64, 128), mesh, rules)
    assert spec == P("model")


@hypothesis.settings(deadline=None, max_examples=30)
@hypothesis.given(
    d0=st.sampled_from([1, 2, 3, 8, 48, 63, 64]),
    d1=st.sampled_from([1, 4, 16, 128, 256]),
)
def test_resolver_locality_invariant(d0, d1):
    """local shape x axis sizes == global shape for every resolution."""
    mesh = _mesh()
    rules = sharding.default_rules(mesh)
    spec = sharding.resolve_spec(("embed", "heads"), (d0, d1), mesh, rules)
    for i, dim in enumerate((d0, d1)):
        ax = spec[i] if i < len(spec) else None
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        assert dim % size == 0


def test_state_sharding_kv_and_seq_shard():
    mesh = _mesh((2, 4))
    rules = sharding.default_rules(mesh)
    # (L,B,S,H,D) with H=1 (MQA): replicated over model by default
    spec = sharding._state_spec_for("k", (8, 4, 64, 1, 16), mesh, rules)
    assert spec == P(None, "data", None, None, None)
    # with kv_seq_shard: sequence dim takes the model axis
    spec = sharding._state_spec_for("k", (8, 4, 64, 1, 16), mesh, rules, kv_seq_shard=True)
    assert spec == P(None, "data", "model", None, None)
    # H divisible: heads win, sequence stays unsharded either way
    spec = sharding._state_spec_for("k", (8, 4, 64, 8, 16), mesh, rules, kv_seq_shard=True)
    assert spec == P(None, "data", None, "model", None)
    # layer dim never decides batch sharding (regression: n_layers % dp != 0)
    spec = sharding._state_spec_for("k", (37, 4, 64, 8, 16), mesh, rules)
    assert spec[1] == "data"


def test_default_rules_multi_pod():
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    rules = sharding.default_rules(mesh)
    assert rules.data_axes == ("pod", "data")
    assert rules.fsdp_axes == ("pod", "data")


def test_param_shardings_tree():
    mesh = _mesh()
    rules = sharding.default_rules(mesh)
    spec_tree = {
        "w": ParamSpec((64, 128), ("embed", "mlp")),
        "n": {"b": ParamSpec((4,), (None,))},
    }
    sh = sharding.param_shardings(spec_tree, mesh, rules)
    assert sh["w"].spec == P("data", "model")
    assert sh["n"]["b"].spec == P()

"""Overlap-scheduled Dslash stencil path.

Pins the PR's acceptance bars:

  * the overlapped ``ExecutionPlan.stencil_step`` is BIT-IDENTICAL to the
    non-overlapped reference on 1-host and (forced-device) multi-host
    meshes, for f32 and bf16-storage/f32-accumulate variants, across the
    SOA and AoSoA planar layouts;
  * the reference itself matches an independent canonical-complex oracle
    (periodic rolls on the (t, z, y, x) 4-D field);
  * ``HaloSpec`` interior/boundary/ghost ranges partition every shard
    exactly (disjoint + covering), including the single-host and
    ``n_shards > L`` slab-degeneracy edge cases;
  * the halo-charging stencil roofline rows carry halo bytes in the
    bandwidth term, and the pruned stencil sweep lands within 5% of its
    exhaustive sweep (same gate as test_autotune_pruning);
  * ``SU3Service`` serves stencil requests through the existing
    warm-pool/batching machinery, mixed with multiplies.
"""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.core.su3 import plan as su3_plan
from repro.core.su3.layouts import Layout, make_codec
from repro.core.su3.plan import EngineConfig, build_plan, stencil_neighbor_tables
from repro.distributed.sharding import HaloSpec, VECTOR_WORDS_PER_SITE
from repro.kernels.su3_stencil import (
    STENCIL_FLOPS_PER_SITE,
    STENCIL_WORDS_PER_SITE,
    stencil_vmem_bytes,
)


def _rand_complex(rng, shape):
    r = rng.standard_normal(shape + (2,)).astype(np.float32)
    return jnp.asarray(r[..., 0] + 1j * r[..., 1], jnp.complex64)


def _pack_inputs(plan, a, v):
    S = a.shape[0]
    if plan.padded_sites > S:
        a = jnp.concatenate(
            [a, jnp.zeros((plan.padded_sites - S, 4, 3, 3), a.dtype)]
        )
    return plan.codec.pack(a), plan.codec.pack_vec(v, plan.padded_sites)


def _oracle(L, a, v):
    """Independent canonical stencil: periodic rolls on the 4-D field.

    out(x) = sum_mu U_mu(x) v(x+mu) + U_mu(x)^dag v(x-mu), with the t-major
    site linearization site = ((t*L + z)*L + y)*L + x.
    """
    S = L**4
    U = np.asarray(a).reshape(L, L, L, L, 4, 3, 3)  # (t, z, y, x, ...)
    V = np.asarray(v).reshape(L, L, L, L, 3)
    out = np.zeros((L, L, L, L, 3), np.complex64)
    axis_of_dir = {0: 3, 1: 2, 2: 1, 3: 0}  # x, y, z, t
    for d in range(4):
        ax = axis_of_dir[d]
        vf = np.roll(V, -1, axis=ax)
        vb = np.roll(V, +1, axis=ax)
        out += np.einsum("...kl,...l->...k", U[..., d, :, :], vf)
        out += np.einsum("...lk,...l->...k", U[..., d, :, :].conj(), vb)
    return out.reshape(S, 3)


# -- reference correctness vs oracle ------------------------------------------


@pytest.mark.parametrize("L,tile", [(2, 8), (4, 64)])
def test_reference_matches_canonical_oracle(L, tile):
    rng = np.random.default_rng(L)
    S = L**4
    p = build_plan(EngineConfig(L=L, tile=tile, iterations=1, warmups=0))
    a, v = _rand_complex(rng, (S, 4, 3, 3)), _rand_complex(rng, (S, 3))
    u_phys, v_p = _pack_inputs(p, a, v)
    got = np.asarray(p.unpack_vec(p.stencil_reference_step()(u_phys, v_p)))
    want = _oracle(L, a, v)
    np.testing.assert_allclose(got, want, atol=2e-4)


def test_fixed_point_verification_and_constants():
    p = build_plan(EngineConfig(L=4, tile=64, iterations=1, warmups=0))
    u, v = p.init_stencil_data()
    out = p.stencil_step(overlap=False)(u, v)
    assert p.verify_stencil(out)
    assert STENCIL_FLOPS_PER_SITE == 576
    assert STENCIL_WORDS_PER_SITE == 126
    assert stencil_vmem_bytes(64) == 126 * 64 * 4
    # padded plans (tile > L**4) stay correct: pad sites self-neighbor
    p_pad = build_plan(EngineConfig(L=2, tile=128, iterations=1, warmups=0))
    assert p_pad.padded_sites > 16
    u, v = p_pad.init_stencil_data()
    assert p_pad.verify_stencil(p_pad.stencil_step(overlap=False)(u, v))


# -- bit-identity: overlap vs reference, single host --------------------------


@pytest.mark.parametrize("layout", [Layout.SOA, Layout.AOSOA])
@pytest.mark.parametrize("dtype,accum", [("float32", ""), ("bfloat16", "float32")])
def test_overlap_bit_identical_single_host(layout, dtype, accum):
    rng = np.random.default_rng(11)
    L, S = 4, 256
    p = build_plan(EngineConfig(
        L=L, tile=64, layout=layout, dtype=dtype, accum_dtype=accum,
        iterations=1, warmups=0,
    ))
    a, v = _rand_complex(rng, (S, 4, 3, 3)), _rand_complex(rng, (S, 3))
    u_phys, v_p = _pack_inputs(p, a, v)
    ref = p.stencil_step(overlap=False)(u_phys, v_p)
    ovl = p.stencil_step(overlap=True)(u_phys, v_p)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(ovl))
    # default schedule on a single-host mesh is the reference
    assert p.stencil_step() is p.stencil_step(overlap=False)


# -- bit-identity: multi-host (forced devices, subprocess) --------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.core.su3.plan import EngineConfig, build_plan
from repro.core.su3.layouts import Layout
from repro.launch.mesh import MeshSpec

rng = np.random.default_rng(5)
def rand_c(shape):
    r = rng.standard_normal(shape + (2,)).astype(np.float32)
    return jnp.asarray(r[..., 0] + 1j * r[..., 1], jnp.complex64)

checked = []
for layout, dtype, accum in (
    ("soa", "float32", ""),
    ("aosoa", "float32", ""),
    ("soa", "bfloat16", "float32"),
    ("aosoa", "bfloat16", "float32"),
):
    L, S = 4, 256
    a, v = rand_c((S, 4, 3, 3)), rand_c((S, 3))
    cfg = EngineConfig(L=L, tile=32, layout=Layout(layout), dtype=dtype,
                       accum_dtype=accum, iterations=1, warmups=0)
    p1 = build_plan(cfg)  # 1-D mesh over 4 devices
    p2 = build_plan(cfg, MeshSpec(hosts=2, devices_per_host=2))
    p4 = build_plan(cfg, MeshSpec(hosts=4, devices_per_host=1))  # slab == face
    assert p2.is_multi_host and p2.stencil_step() is p2.stencil_step(overlap=True)
    outs = []
    for p in (p1, p2, p4):
        u_phys = p.codec.pack(a)
        v_p = p.codec.pack_vec(v, p.padded_sites)
        ref = p.stencil_step(overlap=False)(u_phys, v_p)
        ovl = p.stencil_step(overlap=True)(u_phys, v_p)
        r, o = (np.asarray(jax.device_get(x)) for x in (ref, ovl))
        assert np.array_equal(r, o), (layout, dtype, p.n_hosts)
        outs.append(r.astype(np.float32))
    # same values on every mesh (the multi-host schedules vs single-host)
    assert np.array_equal(outs[0], outs[1]) and np.array_equal(outs[0], outs[2])
    checked.append([layout, dtype, accum])
print(json.dumps(checked))
"""


def test_overlap_bit_identical_multi_host_subprocess(forced_subprocess_json):
    """Forced host-platform devices lock at first jax init, so the 2- and
    4-host (slab-degenerate) meshes run in a subprocess — the shared
    conftest runner."""
    checked = forced_subprocess_json(_SUBPROC)
    assert len(checked) == 4  # 2 layouts x 2 dtype variants


# -- neighbor tables ----------------------------------------------------------


def test_neighbor_tables_local_equals_global_on_interior():
    L, H = 4, 2
    glob, local, bidx = stencil_neighbor_tables(L, L**4, H)
    spec = HaloSpec(L=L, n_shards=H)
    interior = np.concatenate([
        np.arange(a, b) for s in range(H) for (a, b) in spec.interior_ranges(s)
    ] or [np.empty(0, np.int64)]).astype(np.int64)
    boundary = np.concatenate([
        np.arange(a, b) for s in range(H) for (a, b) in spec.boundary_ranges(s)
    ]).astype(np.int64)
    np.testing.assert_array_equal(np.sort(bidx), np.sort(boundary))
    np.testing.assert_array_equal(glob[:, interior], local[:, interior])
    # x/y/z directions are slab-local everywhere
    for d in (0, 1, 2, 4, 5, 6):
        np.testing.assert_array_equal(glob[d], local[d])
    # +-t differ exactly on the boundary sites
    diff = np.where((glob[3] != local[3]) | (glob[7] != local[7]))[0]
    np.testing.assert_array_equal(np.sort(diff), np.sort(boundary))
    # padding sites self-neighbor
    glob_p, local_p, _ = stencil_neighbor_tables(2, 64, 1)
    np.testing.assert_array_equal(glob_p[:, 16:], np.tile(np.arange(16, 64), (8, 1)))


# -- HaloSpec edge cases (satellite) ------------------------------------------


def test_halo_ranges_single_host_no_boundary():
    h = HaloSpec(L=4, n_shards=1)
    assert h.boundary_ranges(0) == [] and h.ghost_ranges(0) == []
    assert h.interior_ranges(0) == [(0, 256)]
    assert h.boundary_sites == 0


@pytest.mark.parametrize("L,n_shards", [
    (4, 2),   # regular two-slab split
    (4, 4),   # slab thickness == one face: all boundary, no interior
    (4, 8),   # n_shards > L: sub-face slab degeneracy
    (4, 16),  # extreme degeneracy
    (2, 2),
])
def test_halo_ranges_partition_exactly(L, n_shards):
    spec = HaloSpec(L=L, n_shards=n_shards)
    for s in range(n_shards):
        lo, hi = spec.shard_range(s)
        ranges = spec.interior_ranges(s) + spec.boundary_ranges(s)
        sites = sorted(x for a, b in ranges for x in range(a, b))
        assert sites == list(range(lo, hi)), (L, n_shards, s)  # disjoint+cover
        for a, b in spec.ghost_ranges(s):
            assert b > a
            assert not (a >= lo and b <= hi), "ghosts must be remote"


def test_halo_degenerate_slab_counts():
    hd = HaloSpec(L=4, n_shards=8)  # per-shard 32 < face 64
    assert hd.sites_per_shard == 32
    assert hd.boundary_sites == 32  # capped at the slab, not 2*face
    assert hd.interior_fraction == 0.0
    assert hd.interior_ranges(0) == []


def test_halo_spec_dtype_and_vector_words():
    from repro.distributed import sharding
    from repro.launch.mesh import MeshSpec
    mesh = MeshSpec(hosts=2, devices_per_host=1).resolve([jax.devices()[0]] * 2)
    assert sharding.halo_spec(4, mesh, dtype="bfloat16").word_bytes == 2
    assert sharding.halo_spec(4, mesh).word_bytes == 4
    h = sharding.halo_spec(4, mesh, words_per_site=VECTOR_WORDS_PER_SITE)
    assert h.halo_bytes_per_exchange == 128 * 6 * 4
    with pytest.raises(ValueError, match="contradicts"):
        sharding.halo_spec(4, mesh, 4, dtype="bfloat16")
    # the plan's stencil halo prices vector words at storage width
    p = build_plan(EngineConfig(L=4, tile=64, dtype="bfloat16",
                                accum_dtype="float32"))
    sh = p.stencil_halo()
    assert sh.words_per_site == 6 and sh.word_bytes == 2


# -- stencil roofline + pruned sweep (same gate as test_autotune_pruning) -----


def test_predict_stencil_charges_halo_in_bandwidth_term():
    c = autotune.StencilCandidate(tile=64, overlap=False)
    p1 = autotune.predict_stencil(c, L=4, hosts=1)
    p2 = autotune.predict_stencil(c, L=4, hosts=2)
    assert p1["halo_s"] == 0.0 and p1["halo_bytes_per_exchange"] == 0
    # vector halo: boundary sites x 6 words x 4 B
    assert p2["halo_bytes_per_exchange"] == 128 * 6 * 4
    stream = 256 * STENCIL_WORDS_PER_SITE * 4
    assert p2["bandwidth_bytes"] == stream + p2["halo_bytes_per_exchange"]
    # all shards run concurrently: the bound composes the PER-SHARD core
    # (core / hosts) with the per-shard halo; serial pays the halo on top
    core = max(p2["compute_s"], p2["memory_s"], p2["issue_s"])
    assert p2["core_shard_s"] == pytest.approx(core / 2)
    assert p2["bound_s"] == pytest.approx(p2["core_shard_s"] + p2["halo_s"])
    # overlapped schedule hides it under the core bound (plus recompute)
    po = autotune.predict_stencil(
        autotune.StencilCandidate(tile=64, overlap=True), L=4, hosts=2)
    assert po["bound_s"] == pytest.approx(
        max(po["core_shard_s"], po["halo_s"])
        + po["boundary_fraction"] * po["core_shard_s"])
    # hosts=1 predicts IDENTICAL schedules; the persisted flag must then be
    # the deterministic serial preference, not measured jitter
    cfgs = [autotune.predict_stencil(
        autotune.StencilCandidate(tile=64, overlap=ov), L=4, hosts=1)
        for ov in (False, True)]
    assert cfgs[0]["bound_s"] == cfgs[1]["bound_s"]


def test_stencil_enumeration_gates_on_vmem():
    # 262144-site tile: 126 words/site x 4 B ~= 126 MiB > 16 MiB VMEM -> out
    cands = autotune.enumerate_stencil_candidates(tiles=(128, 262144))
    assert {c.tile for c in cands} == {128}
    assert {c.overlap for c in cands} == {False, True}
    # a wider accumulate re-inflates the resident set past VMEM
    big = autotune.enumerate_stencil_candidates(tiles=(32768,), overlaps=(False,))
    none = autotune.enumerate_stencil_candidates(
        tiles=(32768,), overlaps=(False,), dtype="float32", accum_dtype="float64")
    assert len(big) == 1 and len(none) == 0


def test_stencil_pruned_sweep_within_5pct_of_exhaustive(monkeypatch):
    """The PR's acceptance bar, stencil edition: measure <= 50% of the
    (tile, overlap) grid; the selected variant's measured GFLOPS within 5%
    of the exhaustive sweep's best."""
    monkeypatch.setattr(
        autotune, "stencil_instruction_model",
        lambda dtype="float32", accum_dtype="", tile=256: 500.0,
    )
    measured = []

    def deterministic_measure(cand):
        measured.append(cand)
        pred = autotune.predict_stencil(cand, L=4, hosts=2)["predicted_gflops"]
        wiggle = 1.0 + 0.03 * math.sin(
            7.0 * cand.tile + (13.0 if cand.overlap else 3.0))
        return {"tile": cand.tile, "overlap": cand.overlap, "vmem_kib": 1,
                "measured_gflops": pred * wiggle, "verified": True}

    exhaustive = autotune.stencil_sweep(
        L=4, hosts=2, prune=1.0, measure_fn=deterministic_measure)
    n_total = exhaustive["candidates_total"]
    assert exhaustive["candidates_measured"] == n_total
    best_exhaustive = max(r["measured_gflops"] for r in exhaustive["rows"])

    measured.clear()
    pruned = autotune.stencil_sweep(
        L=4, hosts=2, prune=0.5, measure_fn=deterministic_measure)
    assert len(measured) == pruned["candidates_measured"]
    assert pruned["candidates_measured"] <= math.ceil(0.5 * n_total)
    best_pruned = max(r["measured_gflops"] for r in pruned["rows"])
    assert best_pruned >= 0.95 * best_exhaustive
    for row in pruned["rows"]:
        assert {"halo_bytes_per_exchange", "bandwidth_bytes",
                "predicted_rank", "halo_s"} <= set(row)


def test_stencil_sweep_real_measurements_tiny_grid():
    # 2 tiles x the (overlap, depth) schedule grid {(F,1), (T,1), (T,2)}
    sweep = autotune.stencil_sweep(
        L=2, prune=0.5, tiles=(8, 16), overlaps=(False, True))
    assert sweep["candidates_total"] == 6
    assert sweep["candidates_measured"] == 3
    for row in sweep["rows"]:
        assert row["verified"], row
        assert row["measured_gflops"] > 0.0


def test_best_stencil_config_persists_and_caches(tmp_path, monkeypatch):
    monkeypatch.setattr(
        autotune, "stencil_instruction_model",
        lambda dtype="float32", accum_dtype="", tile=256: 500.0,
    )

    def stub(cand):
        return {"tile": cand.tile, "overlap": cand.overlap, "vmem_kib": 1,
                "measured_gflops": float(cand.tile + cand.overlap),
                "verified": True}

    cfg = autotune.best_stencil_config(
        L=4, hosts=2, cache_directory=str(tmp_path), measure_fn=stub)
    assert cfg["variant"] == "pallas_stencil" and not cfg["cached"]
    prov = cfg["stencil"]
    assert prov["hosts"] == 2
    assert prov["candidates_measured"] <= math.ceil(
        0.5 * prov["candidates_total"])
    again = autotune.best_stencil_config(
        L=4, hosts=2, cache_directory=str(tmp_path))
    assert again["cached"] and again["stencil"] == prov
    # the multiply cache validator never serves a stencil entry and vice versa
    assert autotune._valid_cache_hit({"config": cfg}) is None


# -- registry / plan wiring ---------------------------------------------------


def test_stencil_kernel_form_rejected_by_multiply_step():
    from repro.core.su3 import registry
    entry = registry.get_kernel("pallas_stencil")
    assert entry.form == registry.STENCIL
    codec = make_codec(Layout.SOA, tile=16)
    with pytest.raises(ValueError, match="stencil"):
        su3_plan.make_raw_step(codec, entry, tile=16)
    assert "pallas_stencil" in registry.kernel_names(form=registry.STENCIL)


def test_vec_codec_roundtrip():
    rng = np.random.default_rng(3)
    for dtype, tol in (("float32", 0.0), ("bfloat16", 1e-2)):
        codec = make_codec(Layout.SOA, tile=16, dtype=dtype)
        v = _rand_complex(rng, (20, 3))
        v_p = codec.pack_vec(v, 32)
        assert v_p.shape == (2, 3, 32)
        back = np.asarray(codec.unpack_vec(v_p, 20))
        if tol:
            np.testing.assert_allclose(back, np.asarray(v), atol=tol)
        else:
            np.testing.assert_array_equal(back, np.asarray(v))


# -- serving ------------------------------------------------------------------


def test_service_serves_stencil_requests_with_multiplies():
    from repro.kernels import ref as kref
    from repro.serve.su3 import BatcherConfig, ServiceConfig, SU3Service

    rng = np.random.default_rng(9)
    svc = SU3Service(ServiceConfig(
        autotune=False, tile=16,
        batcher=BatcherConfig(max_batch=4, warm_batch_sizes=(1, 2, 4),
                              max_queue_depth=32),
    ))
    L, S = 2, 16
    us, vs, sids = [], [], []
    for _ in range(3):
        u, v = _rand_complex(rng, (S, 4, 3, 3)), _rand_complex(rng, (S, 3))
        us.append(u)
        vs.append(v)
        sids.append(svc.submit_stencil(u, v))
    am, bm = _rand_complex(rng, (S, 4, 3, 3)), _rand_complex(rng, (4, 3, 3))
    mid = svc.submit(am, bm, k=2)
    assert svc.run_until_drained() == 4

    # stencil results match the direct plan reference
    p = build_plan(EngineConfig(L=L, tile=16))
    ref_step = p.stencil_step(overlap=False)
    for u, v, rid in zip(us, vs, sids):
        u_phys, v_p = _pack_inputs(p, u, v)
        want = np.asarray(p.unpack_vec(ref_step(u_phys, v_p)))
        got = np.asarray(svc.pop_result(rid))
        np.testing.assert_allclose(got, want, atol=1e-5)
    # the multiply shared the pool and still completed correctly
    want_c = np.asarray(kref.su3_mult_ref(kref.su3_mult_ref(am, bm), bm))
    np.testing.assert_allclose(np.asarray(svc.pop_result(mid)), want_c, atol=1e-4)
    # one warm runner served both request kinds
    assert len(svc.pool_keys()) == 1


def test_service_stencil_validates_vector_shape():
    from repro.serve.su3 import ServiceConfig, SU3Service
    svc = SU3Service(ServiceConfig(autotune=False, tile=16))
    rng = np.random.default_rng(1)
    u = _rand_complex(rng, (16, 4, 3, 3))
    with pytest.raises(ValueError, match="vector field"):
        svc.submit_stencil(u, _rand_complex(rng, (8, 3)))


def test_service_stencil_stream_does_not_starve_chains():
    """Kind fairness: with BOTH kinds pending, turns alternate — a sustained
    stencil stream must not starve a multiply chain already in flight."""
    from repro.serve.su3 import BatcherConfig, ServiceConfig, SU3Service

    rng = np.random.default_rng(13)
    svc = SU3Service(ServiceConfig(
        autotune=False, tile=16, continuous=True,
        batcher=BatcherConfig(max_batch=2, warm_batch_sizes=(1, 2),
                              max_queue_depth=16),
    ))
    S = 16
    am, bm = _rand_complex(rng, (S, 4, 3, 3)), _rand_complex(rng, (4, 3, 3))
    mid = svc.submit(am, bm, k=3)  # needs 3 chain iterations
    u, v = _rand_complex(rng, (S, 4, 3, 3)), _rand_complex(rng, (S, 3))
    for step_n in range(12):
        if svc.has_result(mid):
            break
        svc.submit_stencil(u, v)  # keep the stencil queue non-empty
        svc.step()
    assert svc.has_result(mid), "multiply chain starved by stencil stream"
    svc.run_until_drained()

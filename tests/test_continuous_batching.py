"""Continuous-batching dispatch: InflightChain admission edge cases
(mid-chain admit, incompatible L rejected), L-wide queue popping, service
correctness under mixed chain depths, and the host-sharded pool."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # smoke's fast tier skips these (-m "not slow")

import jax

from repro.kernels import ref
from repro.serve.su3 import (
    BatcherConfig,
    DynamicBatcher,
    InflightChain,
    ServeRequest,
    ServiceConfig,
    SU3Service,
)


def _rand_a(seed, n_sites=16):
    a = jax.random.normal(jax.random.PRNGKey(seed), (n_sites, 4, 3, 3, 2))
    return jax.lax.complex(a[..., 0], a[..., 1])


def _rand_b(seed):
    b = jax.random.normal(jax.random.PRNGKey(seed), (4, 3, 3, 2))
    return jax.lax.complex(b[..., 0], b[..., 1])


def _req(i, L=2, k=1, arrival=0.0):
    return ServeRequest(req_id=i, a=None, b=None, L=L, k=k, arrival_s=arrival or i + 1.0)


def _svc(**kw):
    cfg = dict(autotune=False, tile=16, continuous=True)
    cfg.update(kw)
    return SU3Service(ServiceConfig(**cfg))


# -- InflightChain scheduling (no device needed) ------------------------------


def test_chain_admits_same_L_any_k_until_full():
    chain = InflightChain(L=2, slots=2)
    assert chain.can_admit(_req(0, k=1))
    s0 = chain.admit(_req(0, k=1))
    s1 = chain.admit(_req(1, k=4))  # different k coexists in one chain
    assert {s0, s1} == {0, 1} and chain.live == 2
    assert not chain.can_admit(_req(2))  # full
    with pytest.raises(ValueError, match="full"):
        chain.admit(_req(2))


def test_chain_rejects_incompatible_L():
    chain = InflightChain(L=2, slots=4)
    chain.admit(_req(0, L=2, k=2))
    incompatible = _req(1, L=4)
    assert not chain.can_admit(incompatible)
    with pytest.raises(ValueError, match="incompatible"):
        chain.admit(incompatible)  # must queue for its own chain instead


def test_chain_midchain_admit_and_completion_order():
    chain = InflightChain(L=2, slots=4)
    chain.admit(_req(0, k=3))
    assert not chain.midchain
    assert chain.advance() == []  # r0 has 2 iterations left
    assert chain.midchain
    chain.admit(_req(1, k=1))  # mid-chain admission at an iteration boundary
    done = chain.advance()
    assert [r.req_id for _, r in done] == [1]  # the k=1 joiner finishes first
    done = chain.advance()
    assert [r.req_id for _, r in done] == [0]
    assert chain.live == 0 and chain.occupancy == 0.0
    # fully drained == fresh: a later admit is a new batch, not mid-chain
    assert not chain.midchain
    chain.admit(_req(2, k=1))
    assert not chain.midchain


def test_chain_slot_reuse_after_completion():
    chain = InflightChain(L=2, slots=1)
    chain.admit(_req(0, k=1))
    assert chain.free_slots() == []
    chain.advance()
    assert chain.free_slots() == [0]
    assert chain.admit(_req(1, k=2)) == 0  # freed slot is reused


# -- DynamicBatcher L-wide views ----------------------------------------------


def test_next_for_L_merges_k_buckets_by_arrival():
    b = DynamicBatcher(BatcherConfig(max_batch=8, warm_batch_sizes=(8,)))
    b.submit(_req(0, L=2, k=4, arrival=1.0))
    b.submit(_req(1, L=4, k=1, arrival=2.0))
    b.submit(_req(2, L=2, k=1, arrival=3.0))
    assert b.queued_Ls() == [2, 4]  # oldest head first
    got = b.next_for_L(2, max_n=8)
    assert [r.req_id for r in got] == [0, 2]  # both k buckets, arrival order
    assert len(b) == 1 and b.queued_Ls() == [4]
    assert b.next_for_L(2, max_n=8) == []
    assert b.next_for_L(4, max_n=0) == []


# -- service integration ------------------------------------------------------


def test_continuous_service_matches_reference_mixed_k():
    svc = _svc()
    reqs = []
    for i, k in enumerate([1, 3, 2, 4]):
        a, b = _rand_a(i), _rand_b(100 + i)
        reqs.append((svc.submit(a, b, k=k), a, b, k))
    assert svc.run_until_drained() == 4
    assert not svc.pending()
    for rid, a, b, k in reqs:
        c = svc.pop_result(rid)
        expect = a
        for _ in range(k):
            expect = ref.su3_mult_ref(expect, b)
        np.testing.assert_allclose(
            np.asarray(c), np.asarray(expect), rtol=1e-4, atol=1e-4
        )


def test_continuous_midchain_admission_measured():
    svc = _svc()
    a0, b0 = _rand_a(0), _rand_b(0)
    r0 = svc.submit(a0, b0, k=4)
    svc.step()
    svc.step()  # chain two iterations in
    a1, b1 = _rand_a(1), _rand_b(1)
    r1 = svc.submit(a1, b1, k=1)  # joins the in-flight chain
    svc.run_until_drained()
    assert svc.metrics.midchain_admits == 1
    e0 = a0
    for _ in range(4):
        e0 = ref.su3_mult_ref(e0, b0)
    np.testing.assert_allclose(
        np.asarray(svc.pop_result(r0)), np.asarray(e0), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(svc.pop_result(r1)),
        np.asarray(ref.su3_mult_ref(a1, b1)), rtol=1e-4, atol=1e-4,
    )


def test_continuous_incompatible_L_gets_own_chain():
    svc = _svc()
    r2 = svc.submit(_rand_a(0), _rand_b(0), k=3)  # L=2 chain in flight
    svc.step()
    r4 = svc.submit(_rand_a(1, n_sites=256), _rand_b(1), k=1)  # L=4
    svc.run_until_drained()
    # the L=4 request never joined the L=2 chain: two distinct chains ran
    assert {key[1] for key in svc._chains} <= {2, 4}
    assert svc.metrics.midchain_admits == 0  # no same-L joiner here
    c4 = svc.pop_result(r4)
    np.testing.assert_allclose(
        np.asarray(c4),
        np.asarray(ref.su3_mult_ref(_rand_a(1, n_sites=256), _rand_b(1))),
        rtol=1e-4, atol=1e-4,
    )
    svc.pop_result(r2)


def test_continuous_occupancy_accounting():
    svc = _svc(chain_slots=4)
    for i in range(2):
        svc.submit(_rand_a(i), _rand_b(i), k=2)
    svc.run_until_drained()
    snap = svc.metrics.snapshot()
    # 2 live slots of 4, two iterations: every dispatch at 0.5 occupancy
    assert snap["dispatches"] == 2
    assert snap["mean_batch_occupancy"] == pytest.approx(0.5)
    assert snap["host_dispatches"] == {"0": 2}


# -- host-sharded pool over the simulated host topology -----------------------


def test_multihost_service_routes_by_locality():
    svc = SU3Service(ServiceConfig(autotune=False, tile=16, hosts=2))
    ids = [svc.submit(_rand_a(i), _rand_b(i), k=1) for i in range(2)]  # L=2
    ids.append(svc.submit(_rand_a(9, n_sites=256), _rand_b(9), k=1))  # L=4
    svc.run_until_drained()
    # the two Ls landed on different hosts; pool keys carry the host
    assert {key[0] for key in svc.pool_keys()} == {0, 1}
    assert set(svc.router.assignments()) == {2, 4}
    snap = svc.metrics.snapshot()
    assert set(snap["host_dispatches"]) == {"0", "1"}
    for rid in ids:
        assert svc.pop_result(rid) is not None


def test_multihost_warm_spreads_pool_across_hosts():
    """warm() is a burst of first-sight Ls with no traffic in between; the
    router's nominal placement charge must still spread them (a zero-load
    tie would pin every warmed L — and so all future traffic — to host 0)."""
    svc = SU3Service(ServiceConfig(autotune=False, tile=16, hosts=2))
    svc.warm((2, 4))
    assert {key[0] for key in svc.pool_keys()} == {0, 1}
    homes = svc.router.assignments()
    assert homes[2] != homes[4]


def test_multihost_rejects_explicit_mesh():
    with pytest.raises(ValueError, match="EITHER"):
        SU3Service(ServiceConfig(autotune=False, tile=16, hosts=2), mesh=object())


def test_service_config_validation():
    with pytest.raises(ValueError, match="hosts"):
        ServiceConfig(autotune=False, tile=16, hosts=0)
    with pytest.raises(ValueError, match="chain_slots"):
        ServiceConfig(autotune=False, tile=16, chain_slots=-1)


# -- solve traffic under continuous batching ----------------------------------


def test_solve_mixes_with_continuous_multiply_chains():
    """A CG solve (data-dependent turn count) rides alongside continuous
    multiply chains: chains keep admitting mid-flight while the solve is
    active, the solve retires on its residual test, and every request of
    both kinds completes with the right answer."""
    from repro.core import autotune
    from repro.core.su3.plan import CG_SHIFT, cg_reference_solve

    svc = _svc(solve_iters_per_step=2)
    u, b = autotune._cg_measure_problem(2)
    sid = svc.submit_solve(u, b, tol=1e-6, max_iters=64)
    mult = [(svc.submit(_rand_a(i), _rand_b(i), k=k), i, k)
            for i, k in enumerate([1, 2, 1])]
    solve_done = False
    results = {}
    while svc.pending():
        svc.step()
        for rid, out in svc.pop_ready().items():
            results[rid] = out
            if rid == sid:
                solve_done = True
        if not solve_done and len(results) == len(mult):
            # all multiplies retired while the solve was still in flight:
            # admit one more into the still-warm continuous machinery
            rid = svc.submit(_rand_a(7), _rand_b(7), k=1)
            mult.append((rid, 7, 1))
    assert solve_done and len(results) == len(mult) + 1
    for rid, seed, k in mult:
        expect = _rand_a(seed)
        for _ in range(k):
            expect = ref.su3_mult_ref(expect, _rand_b(seed))
        np.testing.assert_allclose(np.asarray(results[rid]),
                                   np.asarray(expect), rtol=1e-4, atol=1e-4)
    x_ref, _, ok = cg_reference_solve(u, b, 2, sigma=CG_SHIFT, tol=1e-6,
                                      max_iters=64)
    assert ok
    np.testing.assert_allclose(np.asarray(results[sid]), np.asarray(x_ref),
                               rtol=1e-5, atol=1e-5)
    snap = svc.metrics.snapshot()
    ki = snap["kind_iterations"]
    assert 0 < ki["solve"] < 64 and ki.get("multiply", 0) > 0

"""scripts/bench_diff.py: row collection, floor semantics, regression gate."""
import importlib.util
import os

_SPEC = importlib.util.spec_from_file_location(
    "bench_diff",
    os.path.join(os.path.dirname(__file__), "..", "scripts", "bench_diff.py"),
)
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


def _payload(rows_by_table):
    return {"schema": "su3-bench-rows/v1", "tables": rows_by_table}


def test_collect_rows_gathers_engine_and_serve_metrics():
    payload = _payload({
        "table2_variants": [
            {"name": "row_a", "GFLOPS": 1.5},
            {"name": "row_noise", "GFLOPS": 0.01},  # below engine floor
            {"no_name": True, "GFLOPS": 9.9},
        ],
        "serve": [{"name": "serve_open_loop", "sustained_gflops_busy": 0.2}],
        "table1_roofline": [{"name": "analytic", "bw_bound_gf": 141.8}],
    })
    rows = bench_diff.collect_rows(payload)
    assert rows == {
        ("table2_variants", "row_a"): 1.5,
        ("serve", "serve_open_loop"): 0.2,
    }
    # current-side collection keeps sub-floor rows (collapse detection)
    no_floor = bench_diff.collect_rows(payload, apply_floor=False)
    assert no_floor[("table2_variants", "row_noise")] == 0.01


def test_diff_flags_collapse_below_the_noise_floor():
    baseline = _payload({"t": [{"name": "r", "GFLOPS": 2.0}]})
    collapsed = _payload({"t": [{"name": "r", "GFLOPS": 0.03}]})  # ~98% drop
    compared, regressions = bench_diff.diff(baseline, collapsed, 0.15)
    assert len(compared) == 1 and len(regressions) == 1
    assert regressions[0]["delta_pct"] < -90


def test_diff_within_threshold_passes_and_noise_baseline_skipped():
    baseline = _payload({"t": [
        {"name": "steady", "GFLOPS": 1.0},
        {"name": "noise", "GFLOPS": 0.01},  # sub-floor baseline: not gated
    ]})
    current = _payload({"t": [
        {"name": "steady", "GFLOPS": 0.9},  # -10% < 15% threshold
        {"name": "noise", "GFLOPS": 0.001},
    ]})
    compared, regressions = bench_diff.diff(baseline, current, 0.15)
    assert [c["name"] for c in compared] == ["steady"]
    assert regressions == []

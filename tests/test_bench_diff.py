"""scripts/bench_diff.py: row collection, floor semantics, regression gate."""
import importlib.util
import os

_SPEC = importlib.util.spec_from_file_location(
    "bench_diff",
    os.path.join(os.path.dirname(__file__), "..", "scripts", "bench_diff.py"),
)
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


def _payload(rows_by_table):
    return {"schema": "su3-bench-rows/v1", "tables": rows_by_table}


def test_collect_rows_gathers_engine_and_serve_metrics():
    payload = _payload({
        "table2_variants": [
            {"name": "row_a", "GFLOPS": 1.5},
            {"name": "row_noise", "GFLOPS": 0.01},  # below engine floor
            {"no_name": True, "GFLOPS": 9.9},
        ],
        "serve": [{"name": "serve_open_loop", "sustained_gflops_busy": 0.2}],
        "table1_roofline": [{"name": "analytic", "bw_bound_gf": 141.8}],
    })
    rows = bench_diff.collect_rows(payload)
    assert rows == {
        ("table2_variants", "row_a"): 1.5,
        ("serve", "serve_open_loop"): 0.2,
    }
    # current-side collection keeps sub-floor rows (collapse detection)
    no_floor = bench_diff.collect_rows(payload, apply_floor=False)
    assert no_floor[("table2_variants", "row_noise")] == 0.01


def test_diff_flags_collapse_below_the_noise_floor():
    baseline = _payload({"t": [{"name": "r", "GFLOPS": 2.0}]})
    collapsed = _payload({"t": [{"name": "r", "GFLOPS": 0.03}]})  # ~98% drop
    compared, regressions = bench_diff.diff(baseline, collapsed, 0.15)
    assert len(compared) == 1 and len(regressions) == 1
    assert regressions[0]["delta_pct"] < -90


def test_retry_recovers_noise_and_confirms_real_regressions():
    """Flagged rows are re-measured (median of 3): a row whose re-runs
    recover passes; one that stays low is a confirmed regression."""
    baseline = _payload({"t": [
        {"name": "noisy", "GFLOPS": 2.0},
        {"name": "broken", "GFLOPS": 2.0},
    ]})
    current = _payload({"t": [
        {"name": "noisy", "GFLOPS": 1.0},   # -50% single pass (noise)
        {"name": "broken", "GFLOPS": 1.0},  # -50% genuinely
    ]})
    _compared, regressions = bench_diff.diff(baseline, current, 0.15)
    assert len(regressions) == 2

    def fake_remeasure(keys, runs=2, quick=True):
        assert keys == {("t", "noisy"), ("t", "broken")}
        return {("t", "noisy"): [2.1, 1.9],   # recovers: median(1.0,2.1,1.9)=1.9
                ("t", "broken"): [1.05, 0.95]}  # stays low: median=1.0

    still, recovered = bench_diff.retry_regressions(
        regressions, 0.15, remeasure_fn=fake_remeasure)
    assert [r["name"] for r in recovered] == ["noisy"]
    assert recovered[0]["current_median"] == 1.9
    assert recovered[0]["observations"] == 3
    assert [r["name"] for r in still] == ["broken"]
    assert still[0]["delta_pct"] < -40


def test_retry_with_missing_observations_judges_on_what_exists():
    """A re-run that crashes or drops the row contributes nothing; the
    median is over the surviving observations (worst case: the original)."""
    regressions = [{"table": "t", "name": "r", "baseline": 2.0,
                    "current": 1.0, "delta_pct": -50.0}]
    still, recovered = bench_diff.retry_regressions(
        regressions, 0.15, remeasure_fn=lambda keys, **kw: {("t", "r"): []})
    assert recovered == [] and len(still) == 1
    assert still[0]["observations"] == 1


def test_no_retry_flag_fails_single_pass(tmp_path, monkeypatch):
    """--no-retry keeps the old behavior: flagged rows fail immediately,
    and the harness is never re-invoked."""
    base_p = tmp_path / "base.json"
    cur_p = tmp_path / "cur.json"
    import json
    base_p.write_text(json.dumps(_payload({"t": [{"name": "r", "GFLOPS": 2.0}]})))
    cur_p.write_text(json.dumps(_payload({"t": [{"name": "r", "GFLOPS": 1.0}]})))

    def boom(*a, **kw):
        raise AssertionError("remeasure must not run under --no-retry")

    monkeypatch.setattr(bench_diff, "remeasure_rows", boom)
    rc = bench_diff.main(["--baseline", str(base_p), "--current", str(cur_p),
                          "--no-retry"])
    assert rc == 1
    # default path DOES retry (and recovers with a healthy re-measure)
    monkeypatch.setattr(
        bench_diff, "remeasure_rows",
        lambda keys, runs=2, quick=True: {("t", "r"): [2.0, 2.0]})
    rc = bench_diff.main(["--baseline", str(base_p), "--current", str(cur_p)])
    assert rc == 0


def test_diff_within_threshold_passes_and_noise_baseline_skipped():
    baseline = _payload({"t": [
        {"name": "steady", "GFLOPS": 1.0},
        {"name": "noise", "GFLOPS": 0.01},  # sub-floor baseline: not gated
    ]})
    current = _payload({"t": [
        {"name": "steady", "GFLOPS": 0.9},  # -10% < 15% threshold
        {"name": "noise", "GFLOPS": 0.001},
    ]})
    compared, regressions = bench_diff.diff(baseline, current, 0.15)
    assert [c["name"] for c in compared] == ["steady"]
    assert regressions == []


def test_asymmetric_rows_named_both_directions():
    """A row present on only one side is a NAMED warning, never a silent
    skip — a batch of new (e.g. stencil) rows must not mask a dropped one."""
    baseline = _payload({"t": [
        {"name": "kept", "GFLOPS": 1.0},
        {"name": "dropped", "GFLOPS": 2.0},
    ]})
    current = _payload({"t": [
        {"name": "kept", "GFLOPS": 1.0},
        {"name": "brand_new", "GFLOPS": 3.0},
    ], "stencil": [
        {"name": "stencil_L4_float32_overlap", "GFLOPS": 1.2},
    ]})
    only_base, only_cur = bench_diff.asymmetric_rows(baseline, current)
    assert only_base == [("t", "dropped")]
    assert only_cur == [("stencil", "stencil_L4_float32_overlap"),
                        ("t", "brand_new")]


def test_main_prints_asymmetric_warnings(tmp_path, capsys):
    import json
    base_p = tmp_path / "base.json"
    cur_p = tmp_path / "cur.json"
    base_p.write_text(json.dumps(_payload({"t": [
        {"name": "kept", "GFLOPS": 1.0},
        {"name": "dropped", "GFLOPS": 2.0},
    ]})))
    cur_p.write_text(json.dumps(_payload({"t": [
        {"name": "kept", "GFLOPS": 1.0},
        {"name": "brand_new", "GFLOPS": 3.0},
    ]})))
    rc = bench_diff.main(["--baseline", str(base_p), "--current", str(cur_p)])
    err = capsys.readouterr().err
    assert rc == 0  # warnings, not failures
    assert "WARNING row t/dropped" in err and "MISSING" in err
    assert "WARNING row t/brand_new" in err and "new in the current" in err

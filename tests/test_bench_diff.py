"""scripts/bench_diff.py: row collection, floor semantics, regression gate."""
import importlib.util
import os

_SPEC = importlib.util.spec_from_file_location(
    "bench_diff",
    os.path.join(os.path.dirname(__file__), "..", "scripts", "bench_diff.py"),
)
bench_diff = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_diff)


def _payload(rows_by_table):
    return {"schema": "su3-bench-rows/v1", "tables": rows_by_table}


def test_collect_rows_gathers_engine_and_serve_metrics():
    payload = _payload({
        "table2_variants": [
            {"name": "row_a", "GFLOPS": 1.5},
            {"name": "row_noise", "GFLOPS": 0.01},  # below engine floor
            {"no_name": True, "GFLOPS": 9.9},
        ],
        "serve": [{"name": "serve_open_loop", "sustained_gflops_busy": 0.2}],
        "table1_roofline": [{"name": "analytic", "bw_bound_gf": 141.8}],
    })
    rows = bench_diff.collect_rows(payload)
    assert rows == {
        ("table2_variants", "row_a"): 1.5,
        ("serve", "serve_open_loop"): 0.2,
    }
    # current-side collection keeps sub-floor rows (collapse detection)
    no_floor = bench_diff.collect_rows(payload, apply_floor=False)
    assert no_floor[("table2_variants", "row_noise")] == 0.01


def test_diff_flags_collapse_below_the_noise_floor():
    baseline = _payload({"t": [{"name": "r", "GFLOPS": 2.0}]})
    collapsed = _payload({"t": [{"name": "r", "GFLOPS": 0.03}]})  # ~98% drop
    compared, regressions = bench_diff.diff(baseline, collapsed, 0.15)
    assert len(compared) == 1 and len(regressions) == 1
    assert regressions[0]["delta_pct"] < -90


def test_retry_recovers_noise_and_confirms_real_regressions():
    """Flagged rows are re-measured (median of 3): a row whose re-runs
    recover passes; one that stays low is a confirmed regression."""
    baseline = _payload({"t": [
        {"name": "noisy", "GFLOPS": 2.0},
        {"name": "broken", "GFLOPS": 2.0},
    ]})
    current = _payload({"t": [
        {"name": "noisy", "GFLOPS": 1.0},   # -50% single pass (noise)
        {"name": "broken", "GFLOPS": 1.0},  # -50% genuinely
    ]})
    _compared, regressions = bench_diff.diff(baseline, current, 0.15)
    assert len(regressions) == 2

    def fake_remeasure(keys, runs=2, quick=True):
        assert keys == {("t", "noisy"), ("t", "broken")}
        return {("t", "noisy"): [2.1, 1.9],   # recovers: median(1.0,2.1,1.9)=1.9
                ("t", "broken"): [1.05, 0.95]}  # stays low: median=1.0

    still, recovered = bench_diff.retry_regressions(
        regressions, 0.15, remeasure_fn=fake_remeasure)
    assert [r["name"] for r in recovered] == ["noisy"]
    assert recovered[0]["current_median"] == 1.9
    assert recovered[0]["observations"] == 3
    assert [r["name"] for r in still] == ["broken"]
    assert still[0]["delta_pct"] < -40


def test_retry_with_missing_observations_judges_on_what_exists():
    """A re-run that crashes or drops the row contributes nothing; the
    median is over the surviving observations (worst case: the original)."""
    regressions = [{"table": "t", "name": "r", "baseline": 2.0,
                    "current": 1.0, "delta_pct": -50.0}]
    still, recovered = bench_diff.retry_regressions(
        regressions, 0.15, remeasure_fn=lambda keys, **kw: {("t", "r"): []})
    assert recovered == [] and len(still) == 1
    assert still[0]["observations"] == 1


def test_no_retry_flag_fails_single_pass(tmp_path, monkeypatch):
    """--no-retry keeps the old behavior: flagged rows fail immediately,
    and the harness is never re-invoked."""
    base_p = tmp_path / "base.json"
    cur_p = tmp_path / "cur.json"
    import json
    base_p.write_text(json.dumps(_payload({"t": [{"name": "r", "GFLOPS": 2.0}]})))
    cur_p.write_text(json.dumps(_payload({"t": [{"name": "r", "GFLOPS": 1.0}]})))

    def boom(*a, **kw):
        raise AssertionError("remeasure must not run under --no-retry")

    monkeypatch.setattr(bench_diff, "remeasure_rows", boom)
    rc = bench_diff.main(["--baseline", str(base_p), "--current", str(cur_p),
                          "--no-retry"])
    assert rc == 1
    # default path DOES retry (and recovers with a healthy re-measure)
    monkeypatch.setattr(
        bench_diff, "remeasure_rows",
        lambda keys, runs=2, quick=True: {("t", "r"): [2.0, 2.0]})
    rc = bench_diff.main(["--baseline", str(base_p), "--current", str(cur_p)])
    assert rc == 0


def test_diff_within_threshold_passes_and_noise_baseline_skipped():
    baseline = _payload({"t": [
        {"name": "steady", "GFLOPS": 1.0},
        {"name": "noise", "GFLOPS": 0.01},  # sub-floor baseline: not gated
    ]})
    current = _payload({"t": [
        {"name": "steady", "GFLOPS": 0.9},  # -10% < 15% threshold
        {"name": "noise", "GFLOPS": 0.001},
    ]})
    compared, regressions = bench_diff.diff(baseline, current, 0.15)
    assert [c["name"] for c in compared] == ["steady"]
    assert regressions == []


def test_asymmetric_rows_named_both_directions():
    """A row present on only one side is a NAMED warning, never a silent
    skip — a batch of new (e.g. stencil) rows must not mask a dropped one."""
    baseline = _payload({"t": [
        {"name": "kept", "GFLOPS": 1.0},
        {"name": "dropped", "GFLOPS": 2.0},
    ]})
    current = _payload({"t": [
        {"name": "kept", "GFLOPS": 1.0},
        {"name": "brand_new", "GFLOPS": 3.0},
    ], "stencil": [
        {"name": "stencil_L4_float32_overlap", "GFLOPS": 1.2},
    ]})
    only_base, only_cur = bench_diff.asymmetric_rows(baseline, current)
    assert only_base == [("t", "dropped")]
    assert only_cur == [("stencil", "stencil_L4_float32_overlap"),
                        ("t", "brand_new")]


# -- compression / depth-2 gate ----------------------------------------------


def _provenance(**over):
    block = {"git_sha": "deadbeef" * 5, "git_dirty": False,
             "jax_version": "0.4.37", "jaxlib_version": "0.4.36",
             "backend": "cpu", "device_kind": "cpu", "device_count": 1,
             "xla_flags": "", "autotune_cache_schema": 3,
             "python_version": "3.11.0", "platform": "linux"}
    block.update(over)
    return block


def _full_artifact(*, mult_bps=384, mult_bf16_bps=192, st_bps=408,
                   st_bf16_bps=204, identical=True, tag_comp=True,
                   cg_iters=9, cg_tol=1e-6, cg_converged=True,
                   cg_verified=True):
    """A minimal but complete artifact that PASSES the compression, CG, and
    provenance gates; keyword knobs break it in each gated way."""
    comp = "two_row" if tag_comp else "none"
    t2 = [
        {"name": "table2_pallas_I5", "variant": "pallas", "dtype": "float32",
         "compression": "none", "bytes_per_site": 576, "GFLOPS": 1.0},
        {"name": "table2_pallas_two_row_float32", "variant": "pallas",
         "dtype": "float32", "compression": comp,
         "bytes_per_site": mult_bps, "GFLOPS": 1.0},
        {"name": "table2_pallas_two_row_bfloat16_acc-float32",
         "variant": "pallas", "dtype": "bfloat16", "compression": comp,
         "bytes_per_site": mult_bf16_bps, "GFLOPS": 1.0},
    ]
    st = [
        {"name": "stencil_L4_float32_serial", "dtype": "float32",
         "compression": "none", "bytes_per_site": 504, "GFLOPS": 0.5},
        {"name": "stencil_L4_float32_two_row_serial", "dtype": "float32",
         "compression": comp, "bytes_per_site": st_bps, "GFLOPS": 0.5},
        {"name": "stencil_L4_bfloat16_acc-float32_serial",
         "dtype": "bfloat16", "compression": "none",
         "bytes_per_site": 252, "GFLOPS": 0.5},
        {"name": "stencil_L4_bfloat16_acc-float32_two_row_serial",
         "dtype": "bfloat16", "compression": comp,
         "bytes_per_site": st_bf16_bps, "GFLOPS": 0.5},
    ]
    for hosts in (1, 2, 4):
        for t in ("", "_two_row"):
            st.append({"name": f"stencil_depth2_identity_h{hosts}{t}",
                       "hosts": hosts, "identical": identical,
                       "t_two_depth1_us": 100.0, "t_one_depth2_us": 90.0})
    cg = [
        {"name": "cg_residual_vs_time", "tol": cg_tol,
         "iters_to_tol": cg_iters, "converged": cg_converged,
         "GFLOPS": 0.2},
        {"name": "cg_iter_L4_soa_float32_fused", "fused": True,
         "verified": cg_verified, "GFLOPS": 0.1},
        {"name": "cg_iter_L4_soa_float32_composed", "fused": False,
         "GFLOPS": 0.1},
    ]
    chaos = [
        {"name": "serve_chaos", "faults_fired": 8,
         "fired_by_site": {"dispatch": 3, "kernel": 4, "pool": 1},
         "completed_ok": 13, "failed_structured": 0,
         "zero_lost": True, "clean_results_bitwise": True,
         "same_seed_reproduces": True, "p99_inflation": 0.9,
         "p99_inflation_bounded": True, "recovery_max_s": 0.1,
         "GFLOPS": 0.1},
    ]
    tenancy = [
        {"name": "serve_tenancy", "seed": 0, "latency_inflation": 1.2,
         "latency_bounded": True, "jain_fairness": 0.97, "fairness_ok": True,
         "brownout_transitions": 3,
         "brownout_signature": [[3, 0, 1], [9, 1, 2], [14, 2, 0]],
         "brownout_signature_reproduced": True, "quota_rejected": 6,
         "zero_lost": True, "same_seed_reproduces": True,
         "clean_results_bitwise": True, "GFLOPS": 0.1},
    ]
    art = _payload({"table2_variants": t2, "stencil": st, "cg": cg,
                    "chaos": chaos, "tenancy": tenancy})
    art["provenance"] = _provenance()
    return art


def test_compression_gate_passes_on_honest_artifact(capsys):
    problems = bench_diff.compression_gate(_full_artifact())
    assert problems == []
    out = capsys.readouterr().out
    # deltas reported alongside GFLOPS: 384/576 and 408/504
    assert "-33.3%" in out and "-19.0%" in out and "GF/s" in out
    assert "1 exchange saved per 2 applications" in out


def test_compression_gate_fails_silent_fallback_to_18_real():
    # fallback symptom 1: full bytes/site under a two_row name
    problems = bench_diff.compression_gate(
        _full_artifact(mult_bps=576, st_bps=504))
    assert any("ceiling" in p and "two_row_float32" in p for p in problems)
    assert any("stencil_L4_float32_two_row" in p for p in problems)
    # fallback symptom 2: the compression tag itself lost
    problems = bench_diff.compression_gate(_full_artifact(tag_comp=False))
    assert any("does not declare compression" in p for p in problems)
    # 19% stencil reduction passes the 85% ceiling, 15% must not
    assert bench_diff.compression_gate(_full_artifact(st_bps=408)) == []
    assert bench_diff.compression_gate(_full_artifact(st_bps=429))  # 85.1%


def test_compression_gate_fails_missing_and_nonidentical_rows():
    art = _full_artifact()
    art["tables"]["table2_variants"] = [art["tables"]["table2_variants"][0]]  # drop compressed
    art["tables"]["stencil"] = [
        r for r in art["tables"]["stencil"]
        if not r["name"].startswith("stencil_depth2_identity_h4")
    ]
    problems = bench_diff.compression_gate(art)
    assert any("no table2_pallas_two_row_* row for float32" in p
               for p in problems)
    assert any("no table2_pallas_two_row_* row for bfloat16" in p
               for p in problems)
    assert any("stencil_depth2_identity_h4 row missing" in p for p in problems)
    # a depth-2 row that ran but broke bit-identity is a hard failure
    problems = bench_diff.compression_gate(_full_artifact(identical=False))
    assert sum("NOT bit-identical" in p for p in problems) == 6


def test_main_runs_compression_gate_only_on_harness_artifacts(tmp_path):
    import json
    # gated tables present + compressed rows honest -> rc 0 (no baseline)
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_full_artifact()))
    assert bench_diff.main(["--current", str(good),
                            "--baseline", str(tmp_path / "absent.json")]) == 0
    # same artifact with the stencil compressed rows dropped -> rc 1
    bad_art = _full_artifact()
    bad_art["tables"]["stencil"] = [
        r for r in bad_art["tables"]["stencil"] if "_two_row" not in r["name"]]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(bad_art))
    assert bench_diff.main(["--current", str(bad),
                            "--baseline", str(tmp_path / "absent.json")]) == 1
    # ... unless the gate is explicitly skipped (pre-compression artifact)
    assert bench_diff.main(["--current", str(bad),
                            "--baseline", str(tmp_path / "absent.json"),
                            "--no-compression-gate"]) == 0
    # ad-hoc payloads without the gated tables are not gated at all
    adhoc = tmp_path / "adhoc.json"
    adhoc.write_text(json.dumps(_payload({"t": [{"name": "r", "GFLOPS": 1.0}]})))
    assert bench_diff.main(["--current", str(adhoc),
                            "--baseline", str(tmp_path / "absent.json")]) == 0


def test_main_prints_asymmetric_warnings(tmp_path, capsys):
    import json
    base_p = tmp_path / "base.json"
    cur_p = tmp_path / "cur.json"
    base_p.write_text(json.dumps(_payload({"t": [
        {"name": "kept", "GFLOPS": 1.0},
        {"name": "dropped", "GFLOPS": 2.0},
    ]})))
    cur_p.write_text(json.dumps(_payload({"t": [
        {"name": "kept", "GFLOPS": 1.0},
        {"name": "brand_new", "GFLOPS": 3.0},
    ]})))
    rc = bench_diff.main(["--baseline", str(base_p), "--current", str(cur_p)])
    err = capsys.readouterr().err
    assert rc == 0  # warnings, not failures
    assert "WARNING row t/dropped" in err and "MISSING" in err
    assert "WARNING row t/brand_new" in err and "new in the current" in err


# -- provenance gate ----------------------------------------------------------


def test_main_fails_harness_artifact_without_provenance(tmp_path, capsys):
    import json
    art = _full_artifact()
    del art["provenance"]
    cur = tmp_path / "cur.json"
    cur.write_text(json.dumps(art))
    absent = str(tmp_path / "absent.json")
    assert bench_diff.main(["--current", str(cur), "--baseline", absent]) == 1
    assert "provenance" in capsys.readouterr().err
    # escape hatch for pre-provenance artifacts
    assert bench_diff.main(["--current", str(cur), "--baseline", absent,
                            "--no-provenance-gate"]) == 0
    # ad-hoc payloads (no gated tables) are never provenance-gated
    adhoc = tmp_path / "adhoc.json"
    adhoc.write_text(json.dumps(_payload({"t": [{"name": "r", "GFLOPS": 1.0}]})))
    assert bench_diff.main(["--current", str(adhoc), "--baseline", absent]) == 0


def test_main_fails_env_drift_without_rebaseline_note(tmp_path, capsys):
    import json
    base = _full_artifact()
    cur = _full_artifact()
    cur["provenance"] = _provenance(jax_version="0.5.0", jaxlib_version="0.5.0")
    base_p, cur_p = tmp_path / "base.json", tmp_path / "cur.json"
    base_p.write_text(json.dumps(base))
    cur_p.write_text(json.dumps(cur))
    argv = ["--baseline", str(base_p), "--current", str(cur_p)]
    assert bench_diff.main(argv) == 1
    assert "jax_version" in capsys.readouterr().err
    # acknowledged drift passes: CLI note ...
    assert bench_diff.main(argv + ["--rebaseline-note", "jax upgrade"]) == 0
    # ... or a rebaseline field stamped into the artifact itself
    cur["provenance"]["rebaseline"] = "jax upgrade"
    cur_p.write_text(json.dumps(cur))
    assert bench_diff.main(argv) == 0


def test_provenance_problems_unit():
    from repro.obs.provenance import provenance_problems
    art = _full_artifact()
    assert provenance_problems(art) == []
    # missing required key is named
    broken = _full_artifact()
    del broken["provenance"]["backend"]
    assert any("backend" in p for p in provenance_problems(broken))
    # identical env vs baseline is clean; drifted backend is not
    assert provenance_problems(art, _full_artifact()) == []
    drifted = _full_artifact()
    drifted["provenance"]["backend"] = "tpu"
    probs = provenance_problems(drifted, art)
    assert any("backend" in p and "REPRO_BENCH_REBASELINE" in p for p in probs)
    assert provenance_problems(drifted, art, rebaseline_note="tpu run") == []


# -- CG convergence gate -------------------------------------------------------


def test_cg_gate_passes_on_honest_artifact(capsys):
    art = _full_artifact()
    assert bench_diff.cg_gate(art, None) == []
    assert "no committed baseline" in capsys.readouterr().out
    # same iteration count vs a committed baseline is clean
    assert bench_diff.cg_gate(art, _full_artifact()) == []


def test_cg_gate_fails_on_missing_unconverged_or_unverified():
    art = _full_artifact()
    del art["tables"]["cg"]
    assert any("cg_residual_vs_time row missing" in p
               for p in bench_diff.cg_gate(art, None))
    stalled = _full_artifact(cg_converged=False)
    assert any("did NOT converge" in p
               for p in bench_diff.cg_gate(stalled, None))
    unverified = _full_artifact(cg_verified=False)
    assert any("failed verification" in p
               for p in bench_diff.cg_gate(unverified, None))


def test_cg_gate_pins_iterations_to_tolerance():
    base = _full_artifact(cg_iters=10)
    # 10% headroom: 11/10 passes, 12/10 regresses
    assert bench_diff.cg_gate(_full_artifact(cg_iters=11), base) == []
    probs = bench_diff.cg_gate(_full_artifact(cg_iters=12), base)
    assert any("convergence regressed" in p for p in probs)
    # fewer iterations is an improvement, never a failure
    assert bench_diff.cg_gate(_full_artifact(cg_iters=8), base) == []


def test_cg_gate_skips_comparison_on_tol_change(capsys):
    base = _full_artifact(cg_iters=3, cg_tol=1e-3)
    assert bench_diff.cg_gate(_full_artifact(cg_iters=30), base) == []
    assert "different tol" in capsys.readouterr().out


# -- chaos gate ----------------------------------------------------------------


def _chaos_row(**over):
    row = {"name": "serve_chaos", "L": 2, "seed": 0, "faults_fired": 8,
           "fired_by_site": {"dispatch": 3, "kernel": 4, "pool": 1},
           "completed_ok": 13, "failed_structured": 0,
           "zero_lost": True, "clean_results_bitwise": True,
           "same_seed_reproduces": True, "p99_inflation": 0.9,
           "p99_inflation_bounded": True, "recovery_max_s": 0.1,
           "retries": 12, "GFLOPS": 0.1}
    row.update(over)
    return row


def test_chaos_gate_passes_on_honest_row(capsys):
    art = _payload({"chaos": [_chaos_row()]})
    assert bench_diff.chaos_gate(art) == []
    out = capsys.readouterr().out
    assert "8 faults" in out and "same-seed reproduced" in out


def test_chaos_gate_fails_each_broken_contract():
    missing = _payload({"chaos": []})
    assert any("serve_chaos row missing" in p
               for p in bench_diff.chaos_gate(missing))
    errored = _payload({"chaos": [_chaos_row(error="boom")]})
    assert bench_diff.chaos_gate(errored) == ["serve_chaos: row errored: boom"]
    dud = _payload({"chaos": [_chaos_row(faults_fired=0)]})
    assert any("fired no faults" in p for p in bench_diff.chaos_gate(dud))
    for flag, needle in (
        ("zero_lost", "LOST REQUESTS"),
        ("clean_results_bitwise", "NOT bitwise identical"),
        ("same_seed_reproduces", "did NOT reproduce"),
        ("p99_inflation_bounded", "exceeds the ceiling"),
    ):
        # both an explicit False and a silently dropped flag must fail
        for bad in ({flag: False}, {flag: None}):
            art = _payload({"chaos": [_chaos_row(**bad)]})
            assert any(needle in p for p in bench_diff.chaos_gate(art)), flag


def test_main_runs_chaos_gate_on_harness_artifacts(tmp_path):
    import json
    absent = str(tmp_path / "absent.json")
    # a harness artifact (gated tables present) with a broken chaos row fails
    art = _full_artifact()
    art["tables"]["chaos"] = [_chaos_row(zero_lost=False)]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(art))
    assert bench_diff.main(["--current", str(bad), "--baseline", absent]) == 1
    assert bench_diff.main(["--current", str(bad), "--baseline", absent,
                            "--no-chaos-gate"]) == 0
    # honest chaos row passes end to end
    art["tables"]["chaos"] = [_chaos_row()]
    good = tmp_path / "good.json"
    good.write_text(json.dumps(art))
    assert bench_diff.main(["--current", str(good), "--baseline", absent]) == 0


# -- tenancy gate --------------------------------------------------------------


def _tenancy_row(**over):
    row = {"name": "serve_tenancy", "seed": 0, "latency_inflation": 1.2,
           "latency_bounded": True, "jain_fairness": 0.97, "fairness_ok": True,
           "brownout_transitions": 3,
           "brownout_signature": [[3, 0, 1], [9, 1, 2], [14, 2, 0]],
           "brownout_signature_reproduced": True, "quota_rejected": 6,
           "zero_lost": True, "same_seed_reproduces": True,
           "clean_results_bitwise": True, "GFLOPS": 0.1}
    row.update(over)
    return row


def test_tenancy_gate_passes_on_honest_row(capsys):
    art = _payload({"tenancy": [_tenancy_row()]})
    assert bench_diff.tenancy_gate(art) == []
    out = capsys.readouterr().out
    assert "Jain 0.97" in out and "same-seed reproduced" in out


def test_tenancy_gate_fails_each_broken_contract():
    missing = _payload({"tenancy": []})
    assert any("serve_tenancy row missing" in p
               for p in bench_diff.tenancy_gate(missing))
    errored = _payload({"tenancy": [_tenancy_row(error="boom")]})
    assert bench_diff.tenancy_gate(errored) == [
        "serve_tenancy: row errored: boom"]
    # a flood that never tripped the ladder proves nothing
    dud = _payload({"tenancy": [_tenancy_row(brownout_transitions=0)]})
    assert any("never climbed the brownout ladder" in p
               for p in bench_diff.tenancy_gate(dud))
    for flag, needle in (
        ("zero_lost", "LOST REQUESTS"),
        ("latency_bounded", "exceeds the ceiling"),
        ("fairness_ok", "under the floor"),
        ("brownout_signature_reproduced", "brownout transition log"),
        ("same_seed_reproduces", "same fault sequence"),
        ("clean_results_bitwise", "NOT bitwise identical"),
    ):
        # both an explicit False and a silently dropped flag must fail
        for bad in ({flag: False}, {flag: None}):
            art = _payload({"tenancy": [_tenancy_row(**bad)]})
            assert any(needle in p for p in bench_diff.tenancy_gate(art)), flag


def test_main_runs_tenancy_gate_on_harness_artifacts(tmp_path):
    import json
    absent = str(tmp_path / "absent.json")
    art = _full_artifact()
    art["tables"]["tenancy"] = [_tenancy_row(fairness_ok=False)]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(art))
    assert bench_diff.main(["--current", str(bad), "--baseline", absent]) == 1
    assert bench_diff.main(["--current", str(bad), "--baseline", absent,
                            "--no-tenancy-gate"]) == 0
    # honest tenancy row passes end to end
    art["tables"]["tenancy"] = [_tenancy_row()]
    good = tmp_path / "good.json"
    good.write_text(json.dumps(art))
    assert bench_diff.main(["--current", str(good), "--baseline", absent]) == 0

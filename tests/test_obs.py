"""repro.obs: tracer invariants, exports, stats, provenance, attribution.

The observability layer's contract is sharp enough to pin exactly:
spans nest via the context stack, the flight recorder is bounded, the
disabled path allocates nothing, both exports round-trip, and the
attribution join reproduces the roofline's terms for any traced config.
"""
import json

import pytest

from repro.obs import (
    NULL_TRACER,
    REQUIRED_PROVENANCE_KEYS,
    Reservoir,
    RunningStat,
    Tracer,
    attribution_report,
    overlap_efficiency_from_spans,
    provenance_block,
    provenance_problems,
)
from repro.obs.tracer import _NULL_SPAN, load_jsonl


# -- span nesting / ordering --------------------------------------------------


def test_span_nesting_and_completion_order():
    tr = Tracer()
    with tr.span("outer", lane=3) as outer:
        with tr.span("inner") as inner:
            pass
        with tr.span("inner2") as inner2:
            pass
    spans = tr.spans()
    # children complete (enter the ring) before the parent
    assert [s.name for s in spans] == ["inner", "inner2", "outer"]
    assert inner.parent_id == outer.span_id
    assert inner2.parent_id == outer.span_id
    assert outer.parent_id is None
    # lane inheritance: nested spans ride the stack top's lane
    assert inner.lane == 3 and inner2.lane == 3
    # monotonic containment
    assert outer.t0_s <= inner.t0_s <= inner.t1_s <= outer.t1_s
    assert inner.t1_s <= inner2.t0_s  # sequential siblings ordered


def test_retroactive_and_event_spans_attach_to_stack():
    tr = Tracer()
    with tr.span("step") as step:
        tr.add_span("timed", 1.0, 2.0, lane=7, note="retro")
        tr.event("marker", x=1)
    retro = next(s for s in tr.spans() if s.name == "timed")
    marker = next(s for s in tr.spans() if s.name == "marker")
    assert retro.parent_id == step.span_id and retro.dur_s == 1.0
    assert marker.parent_id == step.span_id and marker.dur_s == 0.0
    # explicit parent wins over the stack
    tr.add_span("orphan", 0.0, 1.0, parent_id=None)
    assert tr.spans()[-1].name == "orphan"


def test_out_of_order_exit_does_not_corrupt_stack():
    tr = Tracer()
    a = tr.span("a")
    b = tr.span("b")
    a_span = a.__enter__()
    b.__enter__()
    a.__exit__(None, None, None)  # exits before its child
    b.__exit__(None, None, None)
    with tr.span("after") as after:
        pass
    assert after.parent_id is None  # stack drained despite the misnesting
    assert a_span.span_id is not None


# -- flight-recorder ring -----------------------------------------------------


def test_ring_buffer_evicts_oldest_and_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.add_span(f"s{i}", float(i), float(i) + 0.5)
    assert len(tr) == 4
    assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]
    assert tr.dropped == 6
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


# -- disabled fast path -------------------------------------------------------


def test_disabled_tracer_allocates_nothing():
    assert NULL_TRACER.enabled is False
    # one shared module-level no-op span serves every call
    assert NULL_TRACER.span("a") is NULL_TRACER.span("b")
    assert NULL_TRACER.span("a") is _NULL_SPAN
    with NULL_TRACER.span("a") as s:
        assert s.set(x=1) is s
    assert NULL_TRACER.add_span("x", 0.0, 1.0) is None
    assert NULL_TRACER.event("x") is None
    NULL_TRACER.count("n")
    assert NULL_TRACER.counters == {}
    assert len(NULL_TRACER) == 0
    assert NULL_TRACER.absorb([{"name": "s", "ts_s": 0, "dur_s": 1}]) == 0


# -- exports ------------------------------------------------------------------


def _sample_tracer() -> Tracer:
    tr = Tracer()
    with tr.span("stencil.step", lane=2, L=4, overlap=True):
        with tr.span("stencil.exchange"):
            pass
        with tr.span("stencil.interior"):
            pass
    tr.count("dispatches", 3)
    return tr


def test_jsonl_roundtrip(tmp_path):
    tr = _sample_tracer()
    p = tmp_path / "t.jsonl"
    n = tr.to_jsonl(str(p))
    records = load_jsonl(str(p))
    assert n == len(records) == 4  # 3 spans + 1 counter
    spans = [r for r in records if r["type"] == "span"]
    byname = {r["name"]: r for r in spans}
    assert byname["stencil.exchange"]["parent_id"] == \
        byname["stencil.step"]["span_id"]
    assert records[-1] == {"type": "counter", "name": "dispatches", "value": 3}


def test_chrome_trace_event_validity(tmp_path):
    tr = _sample_tracer()
    payload = tr.chrome_trace(metadata={"git_sha": "abc"})
    assert set(payload) == {"traceEvents", "displayTimeUnit", "otherData"}
    for ev in payload["traceEvents"]:
        # complete events: the exact keys chrome://tracing/Perfetto require
        assert ev["ph"] == "X"
        assert isinstance(ev["ts"], float) and isinstance(ev["dur"], float)
        assert ev["dur"] >= 0.0
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert ev["cat"] == "stencil"
    assert payload["otherData"]["git_sha"] == "abc"
    assert payload["otherData"]["counters"] == {"dispatches": 3}
    p = tmp_path / "t.chrome.json"
    assert tr.to_chrome_trace(str(p)) == 3
    json.load(open(p))  # must be ONE valid JSON document


def test_absorb_preserves_forward_parent_links():
    """Ring order is completion order — children precede parents — so the
    id remap must resolve forward references."""
    sub = _sample_tracer()
    records = [s.as_dict() for s in sub.spans()]
    parent = Tracer()
    with parent.span("local"):
        pass
    assert parent.absorb(records, lane_offset=100) == 3
    byname = {s.name: s for s in parent.spans()}
    step, exch = byname["stencil.step"], byname["stencil.exchange"]
    assert exch.parent_id == step.span_id
    assert step.span_id != records[-1]["span_id"] or True  # remapped ids
    assert step.lane == 102  # lane offset applied
    ids = [s.span_id for s in parent.spans()]
    assert len(ids) == len(set(ids))  # no collisions with local spans


# -- bounded stats ------------------------------------------------------------


def test_reservoir_exact_below_capacity_bounded_above():
    r = Reservoir(capacity=100, seed=0)
    r.extend(float(i) for i in range(50))
    assert len(r) == 50 and sorted(r.sample) == [float(i) for i in range(50)]
    assert r.percentile(50) == pytest.approx(24.5)
    r.extend(float(i) for i in range(50, 100_000))
    assert len(r) == 100_000          # count stays exact
    assert len(r.sample) == 100       # memory stays bounded
    assert r.mean() == pytest.approx(49999.5)  # mean from exact running total
    # the subsample still estimates the distribution (uniform 0..1e5)
    assert r.percentile(50) == pytest.approx(50_000, rel=0.25)


def test_running_stat():
    s = RunningStat()
    assert s.mean() == 0.0 and s.max_or(42) == 42
    for v in (1.0, 3.0, 2.0):
        s.add(v)
    assert s.mean() == pytest.approx(2.0) and s.max_or(0) == 3.0


def test_service_metrics_memory_is_bounded():
    from repro.serve.su3.metrics import LATENCY_RESERVOIR_CAPACITY, ServiceMetrics
    m = ServiceMetrics()
    for i in range(3 * LATENCY_RESERVOIR_CAPACITY):
        m.record_completion(0.010)
        m.record_queue_depth(i % 7)
    assert len(m.latencies_s.sample) == LATENCY_RESERVOIR_CAPACITY
    snap = m.snapshot()
    assert snap["completed"] == 3 * LATENCY_RESERVOIR_CAPACITY
    assert snap["latency_p50_ms"] == pytest.approx(10.0)
    assert snap["queue_depth_max"] == 6


# -- provenance ---------------------------------------------------------------


def test_provenance_block_is_complete():
    block = provenance_block()
    for key in REQUIRED_PROVENANCE_KEYS:
        assert key in block, key
        if key != "xla_flags":  # legitimately empty when the env var is unset
            assert block[key] not in (None, ""), key
    assert block["jax_version"] != "unknown"
    assert len(block["git_sha"]) in (40, len("unknown")) or block["git_sha"]


def test_provenance_problems_names_missing_and_drifted_keys():
    good = {"provenance": provenance_block(), "tables": {}}
    assert provenance_problems(good) == []
    assert provenance_problems({"tables": {}})  # no block at all
    broken = {"provenance": dict(good["provenance"]), "tables": {}}
    del broken["provenance"]["device_kind"]
    assert any("device_kind" in p for p in provenance_problems(broken))
    drifted = {"provenance": dict(good["provenance"], backend="tpu")}
    probs = provenance_problems(drifted, good)
    assert len(probs) == 1 and "backend" in probs[0]
    assert provenance_problems(drifted, good, rebaseline_note="ok") == []
    stamped = {"provenance": dict(drifted["provenance"], rebaseline="tpu day")}
    assert provenance_problems(stamped, good) == []


# -- attribution --------------------------------------------------------------


def _mk_records():
    """Synthetic spans for one multiply config + one overlapped schedule."""
    tr = Tracer()
    for _ in range(3):
        tr.add_span("dispatch", 0.0, 0.010, kind="multiply", L=4, tile=64,
                    k=2, dtype="float32", compression="none", live=4,
                    flops=864.0 * 256 * 2 * 4)
    for _ in range(2):
        with tr.span("stencil.step", L=4, tile=64, overlap=True, depth=1,
                     hosts=2, dtype="float32", compression="none",
                     flops=576.0 * 256):
            with tr.span("stencil.exchange"):
                pass
            with tr.span("stencil.interior"):
                pass
            with tr.span("stencil.boundary"):
                pass
    return tr.spans()


def test_attribution_joins_measured_against_roofline():
    rows = attribution_report(_mk_records())
    by_wl = {r["workload"]: r for r in rows}
    mult = by_wl["multiply"]
    assert mult["n_spans"] == 3 and mult["fused_k"] == 2
    # measured: 3 dispatches x 10ms over 3*4 live requests x k=2 multiplies
    assert mult["measured_unit_s"] == pytest.approx(0.030 / 24)
    assert mult["predicted_s"] is not None and mult["delta_frac"] is not None
    assert mult["model_dominant"] in ("compute", "memory", "issue")
    sched = by_wl["stencil_schedule"]
    assert sched["hosts"] == 2 and sched["overlap"] is True
    assert set(sched["phase_s"]) == {"exchange", "interior", "boundary"}
    assert sched["measured_dominant_phase"] in sched["phase_s"]
    assert sched["model_terms"] is not None and "halo_s" in sched["model_terms"]


def test_attribution_accepts_jsonl_records_and_renders(tmp_path):
    from repro.obs import render_attribution
    tr = Tracer()
    for s in _mk_records():
        tr._record(s)
    p = tmp_path / "t.jsonl"
    tr.to_jsonl(str(p))
    rows = attribution_report(load_jsonl(str(p)))
    assert {r["workload"] for r in rows} == {"multiply", "stencil_schedule"}
    text = render_attribution(rows)
    assert "multiply" in text and "L4/t64" in text and "ovl" in text
    assert render_attribution([]).startswith("(no attributable")


def test_overlap_efficiency_accounting():
    acct = overlap_efficiency_from_spans(_mk_records())
    assert acct["n_steps"] == 2
    assert set(acct["phase_s"]) == {"exchange", "interior", "boundary"}
    assert acct["sum_phases_s"] <= acct["traced_wall_s"]
    assert overlap_efficiency_from_spans([]) is None


# -- traced service (fast: tiny lattice, no autotune) -------------------------


def test_service_emits_request_lifecycle_spans():
    import numpy as np
    import jax.numpy as jnp
    from repro.serve.su3 import BatcherConfig, ServiceConfig, SU3Service

    tracer = Tracer()
    svc = SU3Service(ServiceConfig(
        autotune=False, tile=16,
        batcher=BatcherConfig(max_batch=2, warm_batch_sizes=(2,)),
    ), tracer=tracer)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((16, 4, 3, 3, 2)).astype(np.float32)
    b = rng.standard_normal((4, 3, 3, 2)).astype(np.float32)
    ids = [svc.submit(jnp.asarray(a[..., 0] + 1j * a[..., 1], jnp.complex64),
                      jnp.asarray(b[..., 0] + 1j * b[..., 1], jnp.complex64),
                      k=1) for _ in range(2)]
    svc.run_until_drained()
    for rid in ids:
        svc.pop_result(rid)
    names = [s.name for s in tracer.spans()]
    assert names.count("admit") == 2
    assert "dispatch" in names
    assert names.count("request") == 2
    disp = next(s for s in tracer.spans() if s.name == "dispatch")
    assert disp.attrs["kind"] == "multiply" and disp.attrs["live"] == 2
    req = next(s for s in tracer.spans() if s.name == "request")
    assert req.attrs["queue_wait_s"] >= 0.0
    # request spans cover admission -> completion, so they outlast dispatch
    assert req.dur_s >= disp.dur_s
